//! Training-based figures: 5, 6, 7, 9, 10, 14, 15, 16.
//!
//! Each harness runs real training through the coordinator on whatever
//! [`BackendFactory`] the caller provides and prints the paper's series.
//! `steps` budgets are caller-controlled so smoke tests stay cheap; the
//! recorded runs in EXPERIMENTS.md use the defaults from main.rs.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::trainer::{record_row, StepRecord};
use crate::coordinator::{ddp, Trainer};
use crate::data::{CorpusGenerator, Loader};
use crate::gns::ema::ema_series;
use crate::gns::{linreg, GnsAccumulator, GnsTracker};
use crate::runtime::BackendFactory;
use crate::schedule::{BatchSizeSchedule, LrSchedule};
use crate::telemetry::summary::{mean_curve, tokens_to_reach};
use crate::telemetry::{CsvLogger, TRAIN_HEADER};
use crate::{N_TYPES, STATS_ORDER};

fn base_cfg(model: &str, steps: u64, seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::quickstart(model, steps);
    cfg.seed = seed;
    cfg.lr = LrSchedule {
        max_lr: 1e-3,
        min_lr: 1e-4,
        warmup_steps: steps / 20 + 1,
        decay_steps: steps,
    };
    cfg.corpus_bytes = 1 << 19;
    cfg
}

fn write_records(name: &str, records: &[StepRecord]) -> Result<std::path::PathBuf> {
    let path = super::results_path(name)?;
    let mut csv = CsvLogger::to_file(&path, TRAIN_HEADER)?;
    for r in records {
        csv.row(&record_row(r))?;
    }
    csv.flush()?;
    Ok(path)
}

/// Index of a layer type in the stats order.
fn ti(name: &str) -> usize {
    STATS_ORDER.iter().position(|t| *t == name).unwrap()
}

// ---------------------------------------------------------------------------
// Fig. 5 / Fig. 14: GNS phase plots
// ---------------------------------------------------------------------------

/// Fig. 5 (fixed batch) / Fig. 14 (linear schedule): per-layer-type phase
/// plot of the Eq. 4/5 components and the resulting GNS curves.
pub fn fig5(
    f: &dyn BackendFactory,
    model: &str,
    steps: u64,
    linear_schedule: bool,
) -> Result<()> {
    let mut cfg = base_cfg(model, steps, 0);
    if linear_schedule {
        cfg.batch_size = BatchSizeSchedule::Linear {
            min_accum: 1,
            max_accum: 4,
            ramp_tokens: steps * 2 * cfg_tokens_per_accum(f, model)?,
        };
    }
    let mut tr = Trainer::new(f, cfg)?;
    let out = tr.run()?;
    let name = if linear_schedule { "fig14_phase_linear.csv" } else { "fig5_phase.csv" };
    let path = write_records(name, &out.records)?;

    let fig = if linear_schedule { "Fig. 14" } else { "Fig. 5" };
    println!("{fig}: GNS phase plot ({model}, {steps} steps)");
    println!(
        "{:>6} {:>10} {:>11} {:>11} {:>11} {:>11} {:>9} {:>9}",
        "step", "tokens", "gsq_ln", "s_ln", "gsq_rest", "s_rest", "gns_ln", "gns_tot"
    );
    let every = (steps / 12).max(1);
    let iln = ti("layernorm");
    for r in out.records.iter().filter(|r| r.step % every == 0 || r.step == steps) {
        let gsq_rest: f64 = (0..N_TYPES).filter(|&i| i != iln).map(|i| r.raw_g_sq[i]).sum();
        let s_rest: f64 = (0..N_TYPES).filter(|&i| i != iln).map(|i| r.raw_s[i]).sum();
        println!(
            "{:>6} {:>10} {:>11.3e} {:>11.3e} {:>11.3e} {:>11.3e} {:>9.2} {:>9.2}",
            r.step, r.tokens, r.raw_g_sq[iln], r.raw_s[iln], gsq_rest, s_rest,
            r.gns_layernorm, r.gns_total
        );
    }
    println!("(full series -> {})", path.display());
    println!(
        "shape check: LN components orders of magnitude smaller, but GNS curves track each other"
    );
    Ok(())
}

fn cfg_tokens_per_accum(f: &dyn BackendFactory, model: &str) -> Result<u64> {
    let e = f.describe(model)?;
    Ok((e.microbatch * e.seq_len) as u64)
}

// ---------------------------------------------------------------------------
// Fig. 6: the temperature of training
// ---------------------------------------------------------------------------

/// Fig. 6: fork a run mid-training, varying LR or batch size; GNS should
/// respond to LR (inverse temperature) per McCandlish et al.'s prediction.
pub fn fig6(f: &dyn BackendFactory, model: &str, steps: u64) -> Result<()> {
    let cfg = base_cfg(model, steps, 1);
    let mut tr = Trainer::new(f, cfg)?;
    let warm = steps / 2;
    for _ in 0..warm {
        tr.step()?;
    }
    let snap = tr.snapshot();

    let branches: [(&str, f64, usize); 5] = [
        ("baseline", 1.0, 2),
        ("lr_x2", 2.0, 2),
        ("lr_half", 0.5, 2),
        ("bs_x2", 1.0, 4),
        ("bs_half", 1.0, 1),
    ];
    let path = super::results_path("fig6_temperature.csv")?;
    let mut csv =
        CsvLogger::to_file(&path, &["branch", "step", "gns_total", "gns_layernorm", "loss"])?;
    println!("Fig. 6: GNS response to mid-training LR/BS interventions ({model})");
    println!("{:>10} {:>12} {:>12}", "branch", "gns_before", "gns_after");
    let gns_before = tr.tracker.gns_total().unwrap_or(f64::NAN);
    for (bi, (label, lr_scale, accum)) in branches.iter().enumerate() {
        tr.restore(snap.clone());
        tr.lr_scale = *lr_scale;
        tr.set_batch_schedule(BatchSizeSchedule::Fixed { accum: *accum }, *accum);
        let mut last = f64::NAN;
        for _ in warm..steps {
            let r = tr.step()?;
            csv.row(&[bi as f64, r.step as f64, r.gns_total, r.gns_layernorm, r.loss])?;
            last = r.gns_total;
        }
        println!("{:>10} {:>12.3} {:>12.3}", label, gns_before, last);
    }
    csv.flush()?;
    println!("(series -> {}; branch ids in order {:?})", path.display(),
             branches.map(|b| b.0));
    println!(
        "shape check (paper): GNS rises with lower LR, falls with higher LR; BS changes move \
         it little"
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 7: regression of total GNS on per-layer-type GNS across EMA alphas
// ---------------------------------------------------------------------------

pub fn fig7(f: &dyn BackendFactory, model: &str, steps: u64) -> Result<()> {
    let cfg = base_cfg(model, steps, 2);
    let mut tr = Trainer::new(f, cfg)?;
    let out = tr.run()?;
    write_records("fig7_run.csv", &out.records)?;
    fig7_from_records(&out.records)
}

/// The Fig. 7 analysis itself, reusable on any logged run.
pub fn fig7_from_records(records: &[StepRecord]) -> Result<()> {
    let alphas = [0.5, 0.2, 0.1, 0.05, 0.02, 0.01];
    let path = super::results_path("fig7_regression.csv")?;
    let mut csv = CsvLogger::to_file(&path, &["alpha", "type", "slope", "pearson_r"])?;
    println!("Fig. 7: total-GNS regression per layer type vs EMA alpha");
    println!("{:>6} {:>11} {:>8} {:>9}", "alpha", "type", "slope", "r");
    // skip warmup steps where estimators are still seeding
    let skip = records.len() / 10;
    let recs = &records[skip..];
    for &alpha in &alphas {
        // re-smooth raw components offline at this alpha, ratio last
        let total_g: Vec<f64> = recs.iter().map(|r| r.raw_g_sq_total).collect();
        let total_s: Vec<f64> = recs.iter().map(|r| r.raw_s_total).collect();
        let total_gns: Vec<f64> =
            ratio_series(&ema_series(&total_s, alpha), &ema_series(&total_g, alpha));
        for (t, name) in STATS_ORDER.iter().enumerate() {
            let g: Vec<f64> = recs.iter().map(|r| r.raw_g_sq[t]).collect();
            let s: Vec<f64> = recs.iter().map(|r| r.raw_s[t]).collect();
            let gns = ratio_series(&ema_series(&s, alpha), &ema_series(&g, alpha));
            if let Some(reg) = linreg(&gns, &total_gns) {
                println!("{:>6} {:>11} {:>8.3} {:>9.4}", alpha, name, reg.slope, reg.r);
                csv.row(&[alpha, t as f64, reg.slope, reg.r])?;
            }
        }
    }
    csv.flush()?;
    println!("(series -> {})", path.display());
    println!("shape check (paper): layernorm slope ~1–1.4 with r near 1 across alphas");
    Ok(())
}

fn ratio_series(num: &[f64], den: &[f64]) -> Vec<f64> {
    num.iter()
        .zip(den)
        .map(|(&n, &d)| if d.abs() > 1e-300 { n / d } else { f64::NAN })
        .collect()
}

// ---------------------------------------------------------------------------
// Fig. 9 (+15): batch-size schedule case study
// ---------------------------------------------------------------------------

pub fn fig9(f: &dyn BackendFactory, model: &str, steps: u64, seeds: u64) -> Result<()> {
    let tpa = cfg_tokens_per_accum(f, model)?;
    let max_accum = 4usize;
    let fixed_tokens_per_step = tpa * max_accum as u64;
    let total_tokens = steps * fixed_tokens_per_step;

    let path = super::results_path("fig9_schedule.csv")?;
    let mut csv =
        CsvLogger::to_file(&path, &["variant", "seed", "tokens", "loss", "accum", "gns_total"])?;

    let mut fixed_runs: Vec<Vec<(u64, f64)>> = Vec::new();
    let mut sched_runs: Vec<Vec<(u64, f64)>> = Vec::new();

    for seed in 0..seeds {
        for (vi, linear) in [(0u8, false), (1u8, true)] {
            let mut cfg = base_cfg(model, steps, 10 + seed);
            cfg.batch_size = if linear {
                BatchSizeSchedule::Linear { min_accum: 1, max_accum, ramp_tokens: total_tokens }
            } else {
                BatchSizeSchedule::Fixed { accum: max_accum }
            };
            // token-budget matched: schedule runs until it consumes the
            // same number of tokens as the fixed run
            let mut tr = Trainer::new(f, cfg)?;
            let mut series = Vec::new();
            while tr.tokens() < total_tokens {
                let r = tr.step()?;
                csv.row(&[
                    vi as f64,
                    seed as f64,
                    r.tokens as f64,
                    r.loss,
                    r.accum as f64,
                    r.gns_total,
                ])?;
                series.push((r.tokens, r.loss));
            }
            if linear {
                sched_runs.push(series);
            } else {
                fixed_runs.push(series);
            }
        }
    }
    csv.flush()?;

    // tokens-saved analysis: for loss levels hit by the fixed run, how many
    // fewer tokens did the schedule need?
    println!("Fig. 9: linear batch-size schedule vs fixed ({model}, {seeds} seeds)");
    println!("{:>12} {:>12} {:>12} {:>9}", "loss", "fixed_tok", "sched_tok", "saved%");
    let fixed_mean = mean_curve(&fixed_runs);
    let sched_mean = mean_curve(&sched_runs);
    let mut savings = Vec::new();
    for frac in [0.5, 0.6, 0.7, 0.8, 0.9, 1.0] {
        let idx = ((fixed_mean.len() as f64 * frac) as usize).min(fixed_mean.len() - 1);
        let (ft, fl) = fixed_mean[idx];
        if let Some(st) = tokens_to_reach(&sched_mean, fl) {
            let saved = 100.0 * (ft as f64 - st as f64) / ft as f64;
            println!("{:>12.4} {:>12} {:>12} {:>8.1}%", fl, ft, st, saved);
            savings.push(saved);
        }
    }
    if let Some(last) = savings.last() {
        println!("tokens saved at end of training: {last:.1}% (paper: ~18% wall-time saving)");
    }
    println!("(series -> {})", path.display());
    Ok(())
}

/// Fig. 15: the schedule itself + GNS observed along it.
pub fn fig15(f: &dyn BackendFactory, model: &str, steps: u64) -> Result<()> {
    let tpa = cfg_tokens_per_accum(f, model)?;
    let mut cfg = base_cfg(model, steps, 3);
    cfg.batch_size = BatchSizeSchedule::Linear {
        min_accum: 1,
        max_accum: 4,
        ramp_tokens: steps * 2 * tpa,
    };
    let mut tr = Trainer::new(f, cfg)?;
    let out = tr.run()?;
    let path = write_records("fig15_schedule.csv", &out.records)?;
    println!("Fig. 15: batch-size schedule and observed GNS ({model})");
    println!("{:>6} {:>10} {:>7} {:>9} {:>9}", "step", "tokens", "batch", "gns_tot", "gns_ln");
    let every = (steps / 12).max(1);
    for r in out.records.iter().filter(|r| r.step % every == 0) {
        println!(
            "{:>6} {:>10} {:>7} {:>9.2} {:>9.2}",
            r.step, r.tokens, r.b_big as u64, r.gns_total, r.gns_layernorm
        );
    }
    println!("(series -> {})", path.display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 10: Chinchilla-optimality LR sweep across sizes
// ---------------------------------------------------------------------------

pub fn fig10(f: &dyn BackendFactory, steps: u64) -> Result<()> {
    // FLOP-matched token budgets: steps scaled inversely to params.
    let models = ["sweep70", "small", "sweep161"];
    let lrs = [3e-4, 1e-3, 3e-3];
    let path = super::results_path("fig10_sweep.csv")?;
    let mut csv = CsvLogger::to_file(&path, &["model_params", "lr", "final_loss"])?;
    println!("Fig. 10: LR sweep at three model sizes (FLOP-matched budgets)");
    println!("{:>9} {:>10} {:>8} {:>11}", "model", "params", "lr", "final_loss");
    let base_params = f.describe("small")?.n_params as f64;
    for m in models {
        let entry = f.describe(m)?;
        let scale = base_params / entry.n_params as f64;
        let msteps = ((steps as f64) * scale).round().max(4.0) as u64;
        for &lr in &lrs {
            let mut cfg = base_cfg(m, msteps, 4);
            cfg.lr = LrSchedule {
                max_lr: lr,
                min_lr: lr / 10.0,
                warmup_steps: msteps / 20 + 1,
                decay_steps: msteps,
            };
            let mut tr = Trainer::new(f, cfg)?;
            let out = tr.run()?;
            // average the last 10% of steps for a stable final loss
            let tail = out.records.len() / 10 + 1;
            let fl: f64 = out.records[out.records.len() - tail..]
                .iter()
                .map(|r| r.loss)
                .sum::<f64>()
                / tail as f64;
            println!("{:>9} {:>10} {:>8} {:>11.4}", m, entry.n_params, lr, fl);
            csv.row(&[entry.n_params as f64, lr, fl])?;
        }
    }
    csv.flush()?;
    println!("(series -> {})", path.display());
    println!("shape check: loss minima as LR varies at each scale; mid-size near-optimal");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 16: LN per-example GNS vs simulated-DDP GNS
// ---------------------------------------------------------------------------

pub fn fig16(f: &dyn BackendFactory, model: &str, steps: u64, ranks: usize) -> Result<()> {
    let entry = f.describe(model)?;
    let mut runner = crate::coordinator::ModelRunner::new(f, model)?;
    runner.init(42)?;
    // Rank-parallel engine: each DDP rank runs on its own worker backend.
    let engine = crate::coordinator::ParallelExecutor::new(f, model, ranks)?;
    let text = CorpusGenerator::new(5).generate(1 << 19);
    let base = Loader::new(&text, entry.seq_len, 5);
    let mut loaders: Vec<Loader> = (0..ranks as u64).map(|r| base.for_rank(r)).collect();

    let mut ddp_tracker = GnsTracker::new(&STATS_ORDER, 0.1);
    let mut pex_tracker = GnsTracker::new(&STATS_ORDER, 0.1);
    let lr = LrSchedule {
        max_lr: 1e-3,
        min_lr: 1e-4,
        warmup_steps: steps / 20 + 1,
        decay_steps: steps,
    };

    let path = super::results_path("fig16_ddp_vs_perex.csv")?;
    let mut csv = CsvLogger::to_file(&path, &[
        "step", "loss", "gns_ddp_total", "gns_perex_total", "gns_perex_ln",
    ])?;
    println!("Fig. 16: per-example (LN) GNS vs simulated-DDP GNS ({model}, {ranks} ranks)");
    println!(
        "{:>6} {:>9} {:>11} {:>11} {:>11}",
        "step", "loss", "ddp_gns", "perex_gns", "perex_ln"
    );
    let accum = 1usize;
    let mb = entry.microbatch;
    for step in 1..=steps {
        // per-example stats ride along on each rank's microbatches
        let mut gns_acc = GnsAccumulator::new(N_TYPES, mb);
        // DDP observation (runs the same microbatch streams, in parallel)
        let obs =
            ddp::ddp_step_with_stats(&engine, &runner.params, &mut loaders, accum, &mut gns_acc)?;
        let mut big = [0f64; N_TYPES];
        let n_micro = (ranks * accum) as f64;
        let sums = runner.grad_sqnorms(&obs.mean_grads)?;
        for (d, s) in big.iter_mut().zip(sums) {
            *d = s / (n_micro * n_micro);
        }
        let (small, _) = gns_acc.finish();
        pex_tracker.observe(obs.b_big, &big, &small);
        // DDP tracker: observe from the rank-level components
        ddp_tracker.observe_components(&obs.per_type, &obs.total);

        runner.adamw_update(&obs.mean_grads, lr.at(step), 1.0 / n_micro)?;

        let row = [
            step as f64,
            obs.loss,
            ddp_tracker.gns_total().unwrap_or(f64::NAN),
            pex_tracker.gns_total().unwrap_or(f64::NAN),
            pex_tracker.gns_of("layernorm").unwrap_or(f64::NAN),
        ];
        csv.row(&row)?;
        if step % (steps / 10).max(1) == 0 {
            println!(
                "{:>6} {:>9.4} {:>11.3} {:>11.3} {:>11.3}",
                step, obs.loss, row[2], row[3], row[4]
            );
        }
    }
    csv.flush()?;
    println!("(series -> {})", path.display());
    println!(
        "shape check: LN per-example GNS tracks the DDP estimate (paper corrects a \
         constant-factor bug the same way)"
    );
    Ok(())
}
