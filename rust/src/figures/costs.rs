//! Figures 3 & 4 and Tables 1 & 2: the analytic FLOP/IO cost model.

use anyhow::Result;

use crate::costmodel::linear::{flop_crossover_t, io_crossover_t, linear_cost};
use crate::costmodel::transformer::{transformer_cost, TransformerShape};
use crate::costmodel::Method;
use crate::telemetry::CsvLogger;

/// Model scales swept in Figs. 3/4 (parameter targets).
const SCALES: [(u128, &str); 4] = [
    (125_000_000, "125M"),
    (1_300_000_000, "1.3B"),
    (13_000_000_000, "13B"),
    (175_000_000_000, "175B"),
];

const CONTEXTS: [u128; 6] = [256, 512, 1024, 2048, 4096, 16384];

/// Table 1: FLOP formulae evaluated for a representative layer.
pub fn table1() -> Result<()> {
    println!("Table 1: FLOPs (B=8, K=L=4096)");
    println!("{:<14} {:>22} {:>22}", "Algorithm", "Weight Gradient", "Gradient Norms");
    let (b, k, l) = (8u128, 4096u128, 4096u128);
    for t in [512u128, 4096] {
        println!("-- T = {t}");
        for (m, name) in [(Method::Simultaneous, "Simultaneous"), (Method::Li, "Li et al.")] {
            let c = linear_cost(m, b, t, k, l);
            println!("{:<14} {:>22} {:>22}", name, c.weight_grad_flops, c.norm_flops);
        }
    }
    println!(
        "FLOP crossover T* = sqrt((2KL-1)/(2K+2L-1)) = {:.1}",
        flop_crossover_t(k as f64, l as f64)
    );
    Ok(())
}

/// Table 2: I/O formulae evaluated for a representative layer.
pub fn table2() -> Result<()> {
    println!("Table 2: I/O bytes (B=8, K=L=4096, 4-byte elements)");
    println!("{:<14} {:>22} {:>22}", "Algorithm", "Weight Gradient", "Gradient Norms");
    let (b, k, l) = (8u128, 4096u128, 4096u128);
    for t in [512u128, 4096] {
        println!("-- T = {t}");
        for (m, name) in [(Method::Simultaneous, "Simultaneous"), (Method::Li, "Li et al.")] {
            let c = linear_cost(m, b, t, k, l);
            println!("{:<14} {:>22} {:>22}", name, c.weight_grad_io, c.norm_io);
        }
    }
    println!(
        "I/O crossover T* = sqrt(2KL)/2 = {:.1}",
        io_crossover_t(k as f64, l as f64)
    );
    Ok(())
}

/// Figure 3: FLOP cost of per-example grad norms vs model scale / context.
pub fn fig3() -> Result<()> {
    let path = super::results_path("fig3_flops.csv")?;
    let mut csv = CsvLogger::to_file(&path, &[
        "params", "context", "sim_flops", "li_flops", "ln_flops", "sim_rel", "li_rel",
    ])?;
    println!("Fig. 3: per-example grad-norm FLOPs (batch 8)");
    println!(
        "{:>6} {:>7} {:>12} {:>12} {:>12} {:>9} {:>9}",
        "scale", "ctx", "simul", "li", "ln-only", "sim/fwbw", "li/fwbw"
    );
    for (target, label) in SCALES {
        for ctx in CONTEXTS {
            let shape = TransformerShape::from_params(target, ctx, 8);
            let sim = transformer_cost(&shape, Method::Simultaneous);
            let li = transformer_cost(&shape, Method::Li);
            let ln = transformer_cost(&shape, Method::LnOnly);
            println!(
                "{:>6} {:>7} {:>12.3e} {:>12.3e} {:>12.3e} {:>9.5} {:>9.5}",
                label, ctx, sim.norm_flops as f64, li.norm_flops as f64,
                ln.norm_flops as f64, sim.rel_flops, li.rel_flops
            );
            csv.row(&[
                shape.n_params() as f64,
                ctx as f64,
                sim.norm_flops as f64,
                li.norm_flops as f64,
                ln.norm_flops as f64,
                sim.rel_flops,
                li.rel_flops,
            ])?;
        }
    }
    csv.flush()?;
    println!("(series -> {})", path.display());
    println!("shape check: simultaneous rel-cost is context-independent; Li grows ~T^2");
    Ok(())
}

/// Figure 4: I/O cost, same axes.
pub fn fig4() -> Result<()> {
    let path = super::results_path("fig4_io.csv")?;
    let mut csv = CsvLogger::to_file(&path, &[
        "params", "context", "sim_io", "li_io", "ln_io",
    ])?;
    println!("Fig. 4: per-example grad-norm I/O bytes (batch 8)");
    println!(
        "{:>6} {:>7} {:>12} {:>12} {:>12} {:>10}",
        "scale", "ctx", "simul", "li", "ln-only", "winner"
    );
    for (target, label) in SCALES {
        for ctx in CONTEXTS {
            let shape = TransformerShape::from_params(target, ctx, 8);
            let sim = transformer_cost(&shape, Method::Simultaneous);
            let li = transformer_cost(&shape, Method::Li);
            let ln = transformer_cost(&shape, Method::LnOnly);
            let winner = if sim.norm_io < li.norm_io { "simul" } else { "li" };
            println!(
                "{:>6} {:>7} {:>12.3e} {:>12.3e} {:>12.3e} {:>10}",
                label, ctx, sim.norm_io as f64, li.norm_io as f64, ln.norm_io as f64, winner
            );
            csv.row(&[
                shape.n_params() as f64,
                ctx as f64,
                sim.norm_io as f64,
                li.norm_io as f64,
                ln.norm_io as f64,
            ])?;
        }
    }
    csv.flush()?;
    println!("(series -> {})", path.display());
    println!(
        "shape check: Li wins short-context/large-model; simultaneous wins long context; \
         LN-only far below both"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn harnesses_run() {
        super::table1().unwrap();
        super::table2().unwrap();
    }
}
