//! Figure 2: variance of the GNS estimator vs B_big / B_small, by
//! simulation with jackknife stderr (ratio estimators).
//!
//! Setting mirrors the paper: true GNS = 1; for each (B_big, B_small)
//! pair, process the same number of samples and report the jackknife
//! stderr of the smoothed GNS estimate.

use anyhow::Result;

use crate::gns::{GnsSimulator, SimConfig};
use crate::telemetry::CsvLogger;

pub fn fig2(samples_budget: usize, seeds: u64) -> Result<()> {
    let path = super::results_path("fig2_stderr.csv")?;
    let mut csv = CsvLogger::to_file(&path, &["b_big", "b_small", "gns_est", "stderr"])?;

    println!("Fig. 2 (left): stderr vs B_big at B_small = 1 (true GNS = 1)");
    println!("{:>7} {:>8} {:>10} {:>10}", "b_big", "b_small", "gns", "stderr");
    let mut run = |b_big: usize, b_small: usize| -> Result<(f64, f64)> {
        let mut est_sum = 0.0;
        let mut se_sum = 0.0;
        for seed in 0..seeds {
            let mut sim = GnsSimulator::new(SimConfig { seed, ..SimConfig::default() });
            let steps = (samples_budget / b_big).max(4);
            let (est, se) = sim.estimate(b_big, b_small, steps);
            est_sum += est;
            se_sum += se;
        }
        Ok((est_sum / seeds as f64, se_sum / seeds as f64))
    };

    for b_big in [8usize, 32, 128, 512] {
        let (est, se) = run(b_big, 1)?;
        println!("{:>7} {:>8} {:>10.4} {:>10.4}", b_big, 1, est, se);
        csv.row(&[b_big as f64, 1.0, est, se])?;
    }

    println!("\nFig. 2 (right): stderr vs B_small at B_big = 512");
    println!("{:>7} {:>8} {:>10} {:>10}", "b_big", "b_small", "gns", "stderr");
    for b_small in [1usize, 4, 16, 64, 256] {
        let (est, se) = run(512, b_small)?;
        println!("{:>7} {:>8} {:>10.4} {:>10.4}", 512, b_small, est, se);
        csv.row(&[512.0, b_small as f64, est, se])?;
    }
    csv.flush()?;
    println!("(series -> {})", path.display());
    println!(
        "shape check: stderr flat in B_big, increasing in B_small — per-example (B_small=1) \
         is minimal-variance"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig2_smoke() {
        super::fig2(512, 2).unwrap();
    }
}
