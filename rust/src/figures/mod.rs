//! Figure/table regeneration harness: one function per paper figure.
//!
//! Each harness prints the paper's rows/series to stdout and writes a CSV
//! under `results/` for inspection. Training-based figures accept a step
//! budget so smoke tests can run them cheaply.

pub mod costs;
#[cfg(feature = "pjrt")]
pub mod instability;
pub mod predictor;
pub mod simulation;
pub mod training;

use anyhow::Result;
use std::path::Path;

/// Create `results/` and return the CSV path for a figure id.
pub fn results_path(name: &str) -> Result<std::path::PathBuf> {
    let dir = Path::new("results");
    std::fs::create_dir_all(dir)?;
    Ok(dir.join(name))
}
