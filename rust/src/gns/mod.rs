//! Gradient Noise Scale estimation (paper Section 2.1).
//!
//! The GNS (`B_simple`) is the ratio of two unbiased estimators built from
//! gradient norms at two batch sizes (Eqs. 4, 5):
//!
//! ```text
//! ||G||^2 = (B_big ||G_big||^2 - B_small ||G_small||^2) / (B_big - B_small)
//! S       = (||G_small||^2 - ||G_big||^2) / (1/B_small - 1/B_big)
//! B_simple = S / ||G||^2
//! ```
//!
//! With per-example gradient norms, B_small = 1 and the estimator reaches
//! its minimum variance (Fig. 2). Both components are EMA-smoothed before
//! taking the ratio (paper footnote 7).

pub mod critical;
pub mod ema;
pub mod estimators;
pub mod jackknife;
pub mod regression;
pub mod simulator;
pub mod welford;

pub use ema::{Ema, EmaParts};
pub use estimators::{
    gns_components, GnsAccumulator, GnsComponents, GnsSnapshot, GnsTracker, TrackerState,
    TypeSnapshot,
};
pub use jackknife::jackknife_ratio_stderr;
pub use regression::{linreg, Regression};
pub use simulator::{GnsSimulator, SimConfig};
pub use welford::{OfflineGns, Welford};
