//! Welford running moments, for the *offline* GNS estimation mode of
//! Appendix A: "The estimators of Equation 4 and 5 can then be aggregated
//! using a mean rather than an EMA", with uncertainty from the jackknife.

/// Numerically-stable running mean/variance (Welford), mergeable.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> Option<f64> {
        (self.n > 0).then_some(self.mean)
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> Option<f64> {
        (self.n > 1).then(|| self.m2 / (self.n - 1) as f64)
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> Option<f64> {
        Some((self.var()? / self.n as f64).sqrt())
    }

    /// Parallel merge (Chan et al.) — combine per-rank statistics.
    pub fn merge(&self, other: &Welford) -> Welford {
        if self.n == 0 {
            return other.clone();
        }
        if other.n == 0 {
            return self.clone();
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        Welford { n, mean, m2 }
    }
}

/// Offline GNS aggregate (Appendix A): plain means of the Eq. 4/5
/// components over an observation window, jackknife stderr on the ratio.
#[derive(Debug, Clone, Default)]
pub struct OfflineGns {
    s_obs: Vec<f64>,
    g_obs: Vec<f64>,
}

impl OfflineGns {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, components: super::GnsComponents) {
        self.s_obs.push(components.s);
        self.g_obs.push(components.g_sq);
    }

    pub fn len(&self) -> usize {
        self.s_obs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.s_obs.is_empty()
    }

    /// (GNS point estimate, jackknife stderr); None with < 2 observations.
    pub fn estimate(&self) -> Option<(f64, f64)> {
        (self.len() >= 2).then(|| super::jackknife_ratio_stderr(&self.s_obs, &self.g_obs))
    }

    /// Observations needed for a target relative stderr, extrapolating the
    /// current variance ~ 1/n (the App. A "how long to run offline" use).
    pub fn obs_needed_for(&self, rel_stderr: f64) -> Option<u64> {
        let (est, se) = self.estimate()?;
        if est.abs() < 1e-300 || se == 0.0 {
            return Some(self.len() as u64);
        }
        let current_rel = se / est.abs();
        let factor = (current_rel / rel_stderr).powi(2);
        Some((self.len() as f64 * factor).ceil() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gns::gns_components;

    #[test]
    fn welford_matches_two_pass() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.5, -3.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean: f64 = xs.iter().sum::<f64>() / xs.len() as f64;
        let var: f64 =
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean().unwrap() - mean).abs() < 1e-12);
        assert!((w.var().unwrap() - var).abs() < 1e-12);
    }

    #[test]
    fn merge_equals_concat() {
        let xs: Vec<f64> = (0..50).map(|i| (i as f64 * 0.7).sin() * 3.0).collect();
        let mut all = Welford::new();
        let mut a = Welford::new();
        let mut b = Welford::new();
        for (i, &x) in xs.iter().enumerate() {
            all.push(x);
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
        }
        let m = a.merge(&b);
        assert_eq!(m.count(), all.count());
        assert!((m.mean().unwrap() - all.mean().unwrap()).abs() < 1e-12);
        assert!((m.var().unwrap() - all.var().unwrap()).abs() < 1e-10);
    }

    #[test]
    fn merge_with_empty() {
        let mut a = Welford::new();
        a.push(3.0);
        let e = Welford::new();
        assert_eq!(a.merge(&e).mean(), Some(3.0));
        assert_eq!(e.merge(&a).mean(), Some(3.0));
    }

    #[test]
    fn offline_estimate_converges() {
        // noiseless components -> exact ratio with zero stderr
        let mut off = OfflineGns::new();
        for _ in 0..10 {
            off.push(gns_components(64.0, 1.0 + 4.0 / 64.0, 1.0, 5.0));
        }
        let (est, se) = off.estimate().unwrap();
        assert!((est - 4.0).abs() < 1e-9, "{est}");
        assert!(se < 1e-9);
    }

    #[test]
    fn obs_needed_scales_inverse_square() {
        let mut off = OfflineGns::new();
        // alternating noisy observations
        for i in 0..16 {
            let jitter = if i % 2 == 0 { 0.2 } else { -0.2 };
            off.push(gns_components(64.0, 1.0, 1.0, 3.0 + jitter));
        }
        let (est, se) = off.estimate().unwrap();
        let rel = se / est.abs();
        let need_half = off.obs_needed_for(rel / 2.0).unwrap();
        assert!((need_half as f64 / off.len() as f64 - 4.0).abs() < 0.6, "{need_half}");
    }

    #[test]
    fn prop_welford_mean_in_envelope() {
        crate::util::prop::forall(
            91,
            300,
            |r| {
                let n = r.range(1, 40);
                crate::util::prop::vec_of(r, n, |r| r.range_f64(-100.0, 100.0))
            },
            |xs| {
                let mut w = Welford::new();
                for &x in xs {
                    w.push(x);
                }
                let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
                let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let m = w.mean().unwrap();
                crate::prop_check!(m >= lo - 1e-9 && m <= hi + 1e-9, "mean out of envelope");
                if let Some(v) = w.var() {
                    crate::prop_check!(v >= -1e-9, "negative variance");
                }
                Ok(())
            },
        );
    }
}
