//! Critical-batch-size economics (McCandlish et al. [39], used by the
//! paper's Section 5.2 scheduling argument).
//!
//! With gradient noise scale `B_noise`, training at batch B needs
//!
//! ```text
//! S / S_min = 1 + B_noise / B     (optimizer steps, vs B -> inf)
//! E / E_min = 1 + B / B_noise     (examples processed, vs B -> 0)
//! ```
//!
//! The critical batch `B == B_noise` doubles both relative to their minima —
//! the canonical compute/time tradeoff point. A batch-size *schedule* that
//! tracks the (growing) GNS stays near this point throughout training,
//! which is where the paper's ~18% saving comes from.

/// Relative optimizer steps to reach a loss target at batch `b`.
pub fn step_multiplier(b: f64, b_noise: f64) -> f64 {
    assert!(b > 0.0 && b_noise >= 0.0);
    1.0 + b_noise / b
}

/// Relative examples processed to reach a loss target at batch `b`.
pub fn example_multiplier(b: f64, b_noise: f64) -> f64 {
    assert!(b > 0.0 && b_noise >= 0.0);
    1.0 + b / b_noise.max(1e-300)
}

/// Cost-weighted objective: `time_weight` trades steps against examples;
/// minimized at `B = B_noise * sqrt(time_weight / example_weight)`-free
/// form below uses equal weights, whose optimum is exactly `B_noise`.
pub fn combined_inefficiency(b: f64, b_noise: f64) -> f64 {
    step_multiplier(b, b_noise) * example_multiplier(b, b_noise)
}

/// The batch minimizing [`combined_inefficiency`] (== B_noise).
pub fn optimal_batch(b_noise: f64) -> f64 {
    b_noise
}

/// Expected fraction of examples *wasted* (vs E_min) by running batch `b`
/// when the true noise scale is `b_noise`.
pub fn waste_fraction(b: f64, b_noise: f64) -> f64 {
    1.0 - 1.0 / example_multiplier(b, b_noise)
}

/// Token saving of an adaptive schedule vs a fixed batch, for a GNS
/// trajectory sampled at equal loss-progress intervals.
///
/// For each phase with noise scale `g`, the fixed batch pays
/// `1 + B_fixed/g` examples-per-progress while the tracking schedule
/// (clamped to `B_fixed` — you never exceed the baseline batch, as in the
/// paper's ramp) pays `1 + min(g, B_fixed)/g`. Returns the relative saving
/// in total examples.
pub fn schedule_saving(gns_trajectory: &[f64], b_fixed: f64) -> f64 {
    assert!(!gns_trajectory.is_empty());
    let fixed: f64 = gns_trajectory.iter().map(|&g| example_multiplier(b_fixed, g)).sum();
    let sched: f64 = gns_trajectory
        .iter()
        .map(|&g| example_multiplier(g.clamp(1.0, b_fixed), g))
        .sum();
    1.0 - sched / fixed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multipliers_at_critical_batch() {
        // At B = B_noise both penalties are exactly 2x.
        assert!((step_multiplier(100.0, 100.0) - 2.0).abs() < 1e-12);
        assert!((example_multiplier(100.0, 100.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn limits() {
        // Huge batch: steps -> minimum, examples -> huge.
        assert!((step_multiplier(1e12, 100.0) - 1.0).abs() < 1e-9);
        assert!(example_multiplier(1e12, 100.0) > 1e9);
        // Tiny batch: examples -> minimum.
        assert!((example_multiplier(1e-9, 100.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn combined_minimized_at_b_noise() {
        let bn = 37.0;
        let at_opt = combined_inefficiency(optimal_batch(bn), bn);
        for b in [bn / 8.0, bn / 2.0, bn * 2.0, bn * 8.0] {
            assert!(combined_inefficiency(b, bn) > at_opt, "b={b}");
        }
        assert!((at_opt - 4.0).abs() < 1e-12); // 2 * 2
    }

    #[test]
    fn schedule_saving_positive_for_rising_gns() {
        // GNS ramps from 1 to 64 (the usual training shape); fixed batch 64
        // wastes examples early; tracking it saves a meaningful fraction.
        let traj: Vec<f64> = (0..64).map(|i| 1.0 + i as f64).collect();
        let saving = schedule_saving(&traj, 64.0);
        assert!(saving > 0.1 && saving < 0.9, "{saving}");
        // flat GNS at the fixed batch: nothing to save
        let flat = vec![64.0; 32];
        assert!(schedule_saving(&flat, 64.0).abs() < 1e-12);
    }

    #[test]
    fn prop_waste_in_unit_interval() {
        crate::util::prop::forall(
            92,
            300,
            |r| (r.range_f64(0.1, 1e4), r.range_f64(0.1, 1e4)),
            |&(b, bn)| {
                let w = waste_fraction(b, bn);
                crate::prop_check!((0.0..1.0).contains(&w), "waste {w}");
                Ok(())
            },
        );
    }
}
