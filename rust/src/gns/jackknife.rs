//! Jackknife standard errors for ratio estimators (Choquet, L'Ecuyer &
//! Léger 1999), used for the Fig. 2 error bars: the GNS is a ratio of two
//! correlated unbiased estimators, so naive stderr propagation is biased.

/// Leave-one-out jackknife stderr of `f(mean(xs), mean(ys))`.
///
/// `xs` and `ys` are paired observations (e.g. per-step `S` and `||G||^2`
/// component estimates); `f` is the ratio (or any smooth function) of their
/// means. Returns `(point_estimate, stderr)`.
pub fn jackknife_stderr<F>(xs: &[f64], ys: &[f64], f: F) -> (f64, f64)
where
    F: Fn(f64, f64) -> f64,
{
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    assert!(n >= 2, "jackknife needs >= 2 samples");
    let nf = n as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let theta_hat = f(sx / nf, sy / nf);

    let mut thetas = Vec::with_capacity(n);
    for i in 0..n {
        let mx = (sx - xs[i]) / (nf - 1.0);
        let my = (sy - ys[i]) / (nf - 1.0);
        thetas.push(f(mx, my));
    }
    let mean_theta: f64 = thetas.iter().sum::<f64>() / nf;
    let var: f64 =
        (nf - 1.0) / nf * thetas.iter().map(|t| (t - mean_theta).powi(2)).sum::<f64>();
    (theta_hat, var.sqrt())
}

/// Jackknife stderr of the GNS ratio `S / ||G||^2` from paired per-step
/// component observations.
pub fn jackknife_ratio_stderr(s_obs: &[f64], g_sq_obs: &[f64]) -> (f64, f64) {
    jackknife_stderr(s_obs, g_sq_obs, |s, g| if g.abs() > 1e-300 { s / g } else { f64::NAN })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_variance_inputs_give_zero_stderr() {
        let s = vec![2.0; 10];
        let g = vec![4.0; 10];
        let (est, se) = jackknife_ratio_stderr(&s, &g);
        assert!((est - 0.5).abs() < 1e-12);
        assert!(se.abs() < 1e-12);
    }

    #[test]
    fn linear_function_matches_classic_sem() {
        // For f(x, y) = x, the jackknife reduces to the standard error of
        // the mean of xs.
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let ys = [0.0; 5];
        let (_, se) = jackknife_stderr(&xs, &ys, |x, _| x);
        let mean = 3.0;
        let var: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / 4.0;
        let sem = (var / 5.0).sqrt();
        assert!((se - sem).abs() < 1e-9, "{se} vs {sem}");
    }

    #[test]
    fn more_samples_shrink_stderr() {
        // deterministic synthetic observations with spread
        let mk = |n: usize| -> (Vec<f64>, Vec<f64>) {
            (0..n)
                .map(|i| {
                    let z = ((i * 37 + 11) % 17) as f64 / 17.0 - 0.5;
                    (2.0 + z, 4.0 + 0.5 * z)
                })
                .unzip()
        };
        let (s1, g1) = mk(8);
        let (s2, g2) = mk(512);
        let (_, se1) = jackknife_ratio_stderr(&s1, &g1);
        let (_, se2) = jackknife_ratio_stderr(&s2, &g2);
        assert!(se2 < se1, "{se2} !< {se1}");
    }

    /// stderr is non-negative and finite for well-conditioned inputs.
    #[test]
    fn prop_stderr_nonnegative() {
        crate::util::prop::forall(
            31,
            300,
            |r| {
                let n = r.range(2, 64);
                crate::util::prop::vec_of(r, n, |r| {
                    (r.range_f64(0.1, 10.0), r.range_f64(1.0, 10.0))
                })
            },
            |pairs| {
                let (s, g): (Vec<_>, Vec<_>) = pairs.iter().cloned().unzip();
                let (est, se) = jackknife_ratio_stderr(&s, &g);
                crate::prop_check!(se >= 0.0 && se.is_finite(), "se = {se}");
                crate::prop_check!(est.is_finite(), "est = {est}");
                Ok(())
            },
        );
    }

    /// Permutation invariance: the jackknife is symmetric in samples.
    #[test]
    fn prop_permutation_invariant() {
        crate::util::prop::forall(
            32,
            300,
            |r| {
                let n = r.range(3, 32);
                crate::util::prop::vec_of(r, n, |r| {
                    (r.range_f64(0.1, 10.0), r.range_f64(1.0, 10.0))
                })
            },
            |pairs| {
                let (s, g): (Vec<_>, Vec<_>) = pairs.iter().cloned().unzip();
                let mut rev_s = s.clone();
                rev_s.reverse();
                let mut rev_g = g.clone();
                rev_g.reverse();
                let (e1, se1) = jackknife_ratio_stderr(&s, &g);
                let (e2, se2) = jackknife_ratio_stderr(&rev_s, &rev_g);
                crate::prop_check!((e1 - e2).abs() < 1e-9, "{e1} != {e2}");
                crate::prop_check!((se1 - se2).abs() < 1e-9, "{se1} != {se2}");
                Ok(())
            },
        );
    }
}
