//! Exponential moving average used to smooth the GNS component estimators
//! before taking their ratio (paper footnote 7: "All GNS figures presented
//! in this paper ... smooth both of these estimators").

/// `y_t = alpha * x_t + (1 - alpha) * y_{t-1}`, seeded by the first sample.
///
/// `alpha = 1` disables smoothing. Optional bias correction divides by
/// `1 - (1-alpha)^t` (Adam-style), useful when comparing different alphas
/// early in training (Fig. 7 sweeps alpha over decades).
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    state: Option<f64>,
    t: u64,
    bias_correct: bool,
}

/// Full serializable state of an [`Ema`] (checkpoint/resume).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EmaParts {
    pub alpha: f64,
    pub state: Option<f64>,
    pub t: u64,
    pub bias_correct: bool,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha must be in (0, 1]");
        Self { alpha, state: None, t: 0, bias_correct: false }
    }

    pub fn with_bias_correction(alpha: f64) -> Self {
        let mut e = Self::new(alpha);
        e.bias_correct = true;
        // bias-corrected EMA accumulates from zero rather than seeding
        e.state = Some(0.0);
        e
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Capture the full smoother state (checkpointing).
    pub fn parts(&self) -> EmaParts {
        EmaParts {
            alpha: self.alpha,
            state: self.state,
            t: self.t,
            bias_correct: self.bias_correct,
        }
    }

    /// Rebuild a smoother from captured [`EmaParts`]; resumed updates are
    /// bitwise identical to an uninterrupted smoother.
    pub fn from_parts(p: EmaParts) -> Self {
        assert!(p.alpha > 0.0 && p.alpha <= 1.0, "alpha must be in (0, 1]");
        Self { alpha: p.alpha, state: p.state, t: p.t, bias_correct: p.bias_correct }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        self.t += 1;
        let s = match self.state {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.state = Some(s);
        self.value().unwrap()
    }

    pub fn value(&self) -> Option<f64> {
        let s = self.state?;
        if self.t == 0 {
            return None;
        }
        if self.bias_correct {
            let denom = 1.0 - (1.0 - self.alpha).powi(self.t as i32);
            Some(s / denom)
        } else {
            Some(s)
        }
    }
}

/// Offline EMA over a full series (used by the Fig. 7 regression harness to
/// re-smooth logged raw components at many alphas).
pub fn ema_series(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut e = Ema::new(alpha);
    xs.iter().map(|&x| e.update(x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_with_first_sample() {
        let mut e = Ema::new(0.1);
        assert_eq!(e.value(), None);
        assert_eq!(e.update(5.0), 5.0);
        let v = e.update(0.0);
        assert!((v - 4.5).abs() < 1e-12);
    }

    #[test]
    fn alpha_one_is_identity() {
        let mut e = Ema::new(1.0);
        for x in [3.0, -2.0, 7.5] {
            assert_eq!(e.update(x), x);
        }
    }

    #[test]
    fn bias_correction_recovers_constant() {
        let mut e = Ema::with_bias_correction(0.05);
        for _ in 0..3 {
            e.update(10.0);
        }
        // even after 3 steps, corrected value equals the constant
        assert!((e.value().unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_alpha() {
        Ema::new(0.0);
    }

    #[test]
    fn parts_round_trip_resumes_bitwise() {
        let mut e = Ema::with_bias_correction(0.07);
        for x in [3.0, -1.5, 0.25] {
            e.update(x);
        }
        let mut f = Ema::from_parts(e.parts());
        for x in [9.0, 0.125, -7.0] {
            let a = e.update(x);
            let b = f.update(x);
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// EMA of a constant series is that constant (fixed point).
    #[test]
    fn prop_fixed_point() {
        crate::util::prop::forall(
            21,
            300,
            |r| (r.range_f64(0.01, 1.0), r.range_f64(-1e6, 1e6), r.range(1, 50)),
            |&(alpha, c, n)| {
                let mut e = Ema::new(alpha);
                let mut last = 0.0;
                for _ in 0..n {
                    last = e.update(c);
                }
                crate::prop_check!((last - c).abs() < 1e-6 * c.abs().max(1.0), "{last} != {c}");
                Ok(())
            },
        );
    }

    /// EMA stays within the min/max envelope of its inputs.
    #[test]
    fn prop_stays_in_envelope() {
        crate::util::prop::forall(
            22,
            300,
            |r| {
                let alpha = r.range_f64(0.01, 1.0);
                let n = r.range(1, 50);
                (alpha, crate::util::prop::vec_of(r, n, |r| r.range_f64(-1e3, 1e3)))
            },
            |(alpha, xs)| {
                let mut e = Ema::new(*alpha);
                for &x in xs {
                    e.update(x);
                }
                let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
                let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let v = e.value().unwrap();
                crate::prop_check!(v >= lo - 1e-9 && v <= hi + 1e-9, "{v} outside [{lo}, {hi}]");
                Ok(())
            },
        );
    }
}
