//! Unbiased GNS component estimators (paper Eqs. 4 and 5) and the online
//! per-layer-type tracker used by the coordinator.

use std::collections::BTreeMap;

use super::ema::{Ema, EmaParts};

/// The two unbiased estimators and their ratio for one observation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GnsComponents {
    /// `||G||^2` — estimate of the true squared gradient norm (Eq. 4).
    pub g_sq: f64,
    /// `S` — estimate of `tr(Sigma)`, the gradient noise (Eq. 5).
    pub s: f64,
}

impl GnsComponents {
    /// `B_simple = S / ||G||^2`; None when the denominator is ~0 or the
    /// components are degenerate (NaN/inf).
    pub fn b_simple(&self) -> Option<f64> {
        (self.g_sq.is_finite() && self.g_sq.abs() > 1e-300).then(|| self.s / self.g_sq)
    }
}

/// Compute Eqs. 4 and 5 from squared gradient norms measured at two batch
/// sizes. `norm_sq_small` must already be the *mean* over however many
/// small-batch norms were observed.
///
/// Degenerate inputs (`b_big <= b_small` or `b_small <= 0`, where the
/// estimators are undefined) yield NaN components rather than a division
/// blow-up, so a misconfigured caller sees NaN in its telemetry instead
/// of a plausible-looking garbage GNS.
pub fn gns_components(
    b_big: f64,
    norm_sq_big: f64,
    b_small: f64,
    norm_sq_small: f64,
) -> GnsComponents {
    if !(b_big > b_small && b_small > 0.0) {
        return GnsComponents { g_sq: f64::NAN, s: f64::NAN };
    }
    let g_sq = (b_big * norm_sq_big - b_small * norm_sq_small) / (b_big - b_small);
    let s = (norm_sq_small - norm_sq_big) / (1.0 / b_small - 1.0 / b_big);
    GnsComponents { g_sq, s }
}

/// Accumulates the per-microbatch statistics of one optimizer step.
///
/// The grad_step artifact reports, per layer type, `sum_b ||w'_b||^2` where
/// `w'_b = (1/B_micro) dL_b/dw` (gradients of the *mean-microbatch* loss).
/// Algorithm 1 step 4's correction to per-example scale is
/// `mean_b ||dL_b/dw||^2 = B_micro * sum_b ||w'_b||^2`, applied here.
#[derive(Debug, Clone)]
pub struct GnsAccumulator {
    microbatch: usize,
    /// Per layer-type running sum of per-example squared norms (corrected).
    perex_sum: Vec<f64>,
    /// Number of examples folded into `perex_sum`.
    n_examples: usize,
}

impl GnsAccumulator {
    pub fn new(n_types: usize, microbatch: usize) -> Self {
        Self { microbatch, perex_sum: vec![0.0; n_types], n_examples: 0 }
    }

    /// Fold one microbatch's stats vector (raw `sum_b ||w'_b||^2` per type).
    pub fn add_microbatch(&mut self, stats: &[f32]) {
        assert_eq!(stats.len(), self.perex_sum.len());
        let b = self.microbatch as f64;
        for (acc, &s) in self.perex_sum.iter_mut().zip(stats) {
            // sum_b ||dL_b||^2 = B^2 * sum_b ||w'_b||^2; we accumulate the
            // sum and divide by total examples at finish() for the mean.
            *acc += b * b * (s as f64);
        }
        self.n_examples += self.microbatch;
    }

    pub fn n_examples(&self) -> usize {
        self.n_examples
    }

    /// Fold another accumulator over the *same* layer types and
    /// microbatch size into this one (the rank-parallel reduction step).
    /// Merging per-rank accumulators in a fixed order is the stats-side
    /// analogue of the gradient tree reduction: each partial sum is a
    /// plain f64 sum over its own microbatches, so `merge` preserves the
    /// deterministic association the coordinator documents.
    pub fn merge(&mut self, other: &GnsAccumulator) {
        assert_eq!(self.perex_sum.len(), other.perex_sum.len(), "layer-type arity mismatch");
        assert_eq!(self.microbatch, other.microbatch, "microbatch mismatch");
        for (a, b) in self.perex_sum.iter_mut().zip(&other.perex_sum) {
            *a += b;
        }
        self.n_examples += other.n_examples;
    }

    /// Decompose into `(microbatch, perex_sum, n_examples)` for wire
    /// transport. The parts are exact f64 sums, so a remote accumulator
    /// rebuilt via [`GnsAccumulator::from_parts`] merges bitwise
    /// identically to one that stayed in-process.
    pub fn export_parts(&self) -> (usize, Vec<f64>, usize) {
        (self.microbatch, self.perex_sum.clone(), self.n_examples)
    }

    /// Rebuild an accumulator from [`GnsAccumulator::export_parts`] output.
    pub fn from_parts(microbatch: usize, perex_sum: Vec<f64>, n_examples: usize) -> Self {
        Self { microbatch, perex_sum, n_examples }
    }

    /// Mean per-example squared norm per layer type (`||G_Bsmall||^2` with
    /// B_small = 1), plus the total.
    pub fn finish(&self) -> (Vec<f64>, f64) {
        let n = self.n_examples.max(1) as f64;
        let per_type: Vec<f64> = self.perex_sum.iter().map(|s| s / n).collect();
        let total = per_type.iter().sum();
        (per_type, total)
    }
}

/// Online per-layer-type GNS tracker: EMA-smooths the Eq. 4/5 components
/// separately (paper footnote 7) and exposes smoothed `B_simple` per type
/// and for the whole model.
#[derive(Debug, Clone)]
pub struct GnsTracker {
    types: Vec<String>,
    ema_g_sq: Vec<Ema>,
    ema_s: Vec<Ema>,
    ema_g_sq_total: Ema,
    ema_s_total: Ema,
    /// Most recent raw (unsmoothed) components per type.
    pub last_raw: Vec<GnsComponents>,
    pub last_raw_total: Option<GnsComponents>,
}

/// Full serializable state of a [`GnsTracker`] (checkpoint/resume): every
/// EMA's exact state, so a resumed tracker continues the smoothed series
/// bitwise identically. The transient `last_raw*` fields are *not* part of
/// the state — they are overwritten by the first `observe` after resume,
/// before anything reads them.
#[derive(Debug, Clone, PartialEq)]
pub struct TrackerState {
    pub types: Vec<String>,
    pub g_sq: Vec<EmaParts>,
    pub s: Vec<EmaParts>,
    pub g_sq_total: EmaParts,
    pub s_total: EmaParts,
}

#[derive(Debug, Clone)]
pub struct GnsSnapshot {
    pub per_type: BTreeMap<String, TypeSnapshot>,
    pub total: TypeSnapshot,
}

#[derive(Debug, Clone)]
pub struct TypeSnapshot {
    pub g_sq: f64,
    pub s: f64,
    pub gns: Option<f64>,
}

impl GnsTracker {
    pub fn new(types: &[&str], alpha: f64) -> Self {
        Self {
            types: types.iter().map(|s| s.to_string()).collect(),
            ema_g_sq: vec![Ema::new(alpha); types.len()],
            ema_s: vec![Ema::new(alpha); types.len()],
            ema_g_sq_total: Ema::new(alpha),
            ema_s_total: Ema::new(alpha),
            last_raw: Vec::new(),
            last_raw_total: None,
        }
    }

    /// Capture the tracker's full EMA state (checkpointing).
    pub fn export_state(&self) -> TrackerState {
        TrackerState {
            types: self.types.clone(),
            g_sq: self.ema_g_sq.iter().map(Ema::parts).collect(),
            s: self.ema_s.iter().map(Ema::parts).collect(),
            g_sq_total: self.ema_g_sq_total.parts(),
            s_total: self.ema_s_total.parts(),
        }
    }

    /// Rebuild a tracker from a captured [`TrackerState`].
    pub fn from_state(st: TrackerState) -> Self {
        assert_eq!(st.g_sq.len(), st.types.len(), "g_sq arity mismatch");
        assert_eq!(st.s.len(), st.types.len(), "s arity mismatch");
        Self {
            types: st.types,
            ema_g_sq: st.g_sq.into_iter().map(Ema::from_parts).collect(),
            ema_s: st.s.into_iter().map(Ema::from_parts).collect(),
            ema_g_sq_total: Ema::from_parts(st.g_sq_total),
            ema_s_total: Ema::from_parts(st.s_total),
            last_raw: Vec::new(),
            last_raw_total: None,
        }
    }

    /// Layer-type names in stats order.
    pub fn types(&self) -> &[String] {
        &self.types
    }

    /// Observe one optimizer step.
    ///
    /// * `big_sq[t]` — squared norm of the accumulated (big-batch, i.e.
    ///   mean over `b_big` examples) gradient, per layer type;
    /// * `small_sq[t]` — mean per-example squared norm per type (from
    ///   [`GnsAccumulator::finish`]);
    /// * `b_big` — effective batch size of the accumulated gradient.
    pub fn observe(&mut self, b_big: f64, big_sq: &[f64], small_sq: &[f64]) {
        assert_eq!(big_sq.len(), self.types.len());
        assert_eq!(small_sq.len(), self.types.len());
        self.last_raw.clear();
        let mut tot_big = 0.0;
        let mut tot_small = 0.0;
        for i in 0..self.types.len() {
            let c = gns_components(b_big, big_sq[i], 1.0, small_sq[i]);
            self.ema_g_sq[i].update(c.g_sq);
            self.ema_s[i].update(c.s);
            self.last_raw.push(c);
            tot_big += big_sq[i];
            tot_small += small_sq[i];
        }
        let ct = gns_components(b_big, tot_big, 1.0, tot_small);
        self.ema_g_sq_total.update(ct.g_sq);
        self.ema_s_total.update(ct.s);
        self.last_raw_total = Some(ct);
    }

    /// Observe pre-computed components directly (e.g. from the DDP
    /// estimator, which uses B_small = rank batch rather than 1).
    pub fn observe_components(&mut self, per_type: &[GnsComponents], total: &GnsComponents) {
        assert_eq!(per_type.len(), self.types.len());
        self.last_raw.clear();
        for (i, c) in per_type.iter().enumerate() {
            self.ema_g_sq[i].update(c.g_sq);
            self.ema_s[i].update(c.s);
            self.last_raw.push(*c);
        }
        self.ema_g_sq_total.update(total.g_sq);
        self.ema_s_total.update(total.s);
        self.last_raw_total = Some(*total);
    }

    /// Smoothed GNS per layer type; None until first observation.
    pub fn gns_of(&self, ltype: &str) -> Option<f64> {
        let i = self.types.iter().position(|t| t == ltype)?;
        let g = self.ema_g_sq[i].value()?;
        let s = self.ema_s[i].value()?;
        (g.abs() > 1e-300).then(|| s / g)
    }

    /// Smoothed total GNS.
    pub fn gns_total(&self) -> Option<f64> {
        let g = self.ema_g_sq_total.value()?;
        let s = self.ema_s_total.value()?;
        (g.abs() > 1e-300).then(|| s / g)
    }

    pub fn snapshot(&self) -> GnsSnapshot {
        let mut per_type = BTreeMap::new();
        for (i, t) in self.types.iter().enumerate() {
            per_type.insert(
                t.clone(),
                TypeSnapshot {
                    g_sq: self.ema_g_sq[i].value().unwrap_or(f64::NAN),
                    s: self.ema_s[i].value().unwrap_or(f64::NAN),
                    gns: self.gns_of(t),
                },
            );
        }
        GnsSnapshot {
            per_type,
            total: TypeSnapshot {
                g_sq: self.ema_g_sq_total.value().unwrap_or(f64::NAN),
                s: self.ema_s_total.value().unwrap_or(f64::NAN),
                gns: self.gns_total(),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn components_exact_on_noiseless_gradient() {
        // With zero noise, ||G_big||^2 == ||G_small||^2 == ||G||^2:
        // S must be 0 and g_sq the common value.
        let c = gns_components(64.0, 4.0, 1.0, 4.0);
        assert!((c.g_sq - 4.0).abs() < 1e-12);
        assert!(c.s.abs() < 1e-12);
        assert_eq!(c.b_simple(), Some(0.0));
    }

    #[test]
    fn components_match_expected_values() {
        // E||G_B||^2 = ||G||^2 + tr(Sigma)/B. Take ||G||^2 = 2, tr = 6.
        let (g2, tr) = (2.0, 6.0);
        let big = g2 + tr / 8.0;
        let small = g2 + tr / 1.0;
        let c = gns_components(8.0, big, 1.0, small);
        assert!((c.g_sq - g2).abs() < 1e-12, "{c:?}");
        assert!((c.s - tr).abs() < 1e-12, "{c:?}");
        assert!((c.b_simple().unwrap() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn accumulator_applies_b_squared_correction() {
        let mut acc = GnsAccumulator::new(2, 4);
        // raw stats from grad_step: sum_b ||w'_b||^2
        acc.add_microbatch(&[1.0, 0.5]);
        acc.add_microbatch(&[3.0, 0.5]);
        let (per_type, total) = acc.finish();
        // corrected: 16*(1+3)/8 = 8, 16*(0.5+0.5)/8 = 2
        assert!((per_type[0] - 8.0).abs() < 1e-12);
        assert!((per_type[1] - 2.0).abs() < 1e-12);
        assert!((total - 10.0).abs() < 1e-12);
        assert_eq!(acc.n_examples(), 8);
    }

    #[test]
    fn accumulator_merge_matches_single_accumulator() {
        let mut whole = GnsAccumulator::new(2, 4);
        let mut left = GnsAccumulator::new(2, 4);
        let mut right = GnsAccumulator::new(2, 4);
        for (i, stats) in [[1.0f32, 0.5], [3.0, 0.25], [2.0, 0.125], [0.5, 8.0]]
            .iter()
            .enumerate()
        {
            whole.add_microbatch(stats);
            if i < 2 {
                left.add_microbatch(stats);
            } else {
                right.add_microbatch(stats);
            }
        }
        left.merge(&right);
        assert_eq!(left.n_examples(), whole.n_examples());
        let (a, at) = left.finish();
        let (b, bt) = whole.finish();
        // dyadic inputs: every partial sum is exact in f64, so the merged
        // result is bitwise equal regardless of association
        assert_eq!(a[0].to_bits(), b[0].to_bits());
        assert_eq!(a[1].to_bits(), b[1].to_bits());
        assert_eq!(at.to_bits(), bt.to_bits());
    }

    #[test]
    fn tracker_state_round_trip_resumes_bitwise() {
        let mut tr = GnsTracker::new(&["a", "b"], 0.25);
        tr.observe(16.0, &[1.0, 2.0], &[5.0, 6.0]);
        tr.observe(32.0, &[1.5, 2.5], &[4.0, 7.0]);
        let mut resumed = GnsTracker::from_state(tr.export_state());
        tr.observe(16.0, &[0.5, 0.25], &[3.0, 1.0]);
        resumed.observe(16.0, &[0.5, 0.25], &[3.0, 1.0]);
        assert_eq!(tr.gns_total().unwrap().to_bits(), resumed.gns_total().unwrap().to_bits());
        assert_eq!(tr.gns_of("a").unwrap().to_bits(), resumed.gns_of("a").unwrap().to_bits());
        assert_eq!(tr.types(), resumed.types());
    }

    #[test]
    fn tracker_total_is_sum_of_components() {
        let mut tr = GnsTracker::new(&["a", "b"], 1.0); // alpha=1: no smoothing
        tr.observe(16.0, &[1.0, 2.0], &[5.0, 6.0]);
        let ca = tr.last_raw[0];
        let cb = tr.last_raw[1];
        let ct = tr.last_raw_total.unwrap();
        assert!((ct.g_sq - (ca.g_sq + cb.g_sq)).abs() < 1e-12);
        assert!((ct.s - (ca.s + cb.s)).abs() < 1e-12);
        assert!(tr.gns_total().is_some());
        assert!(tr.gns_of("a").is_some());
        assert!(tr.gns_of("zzz").is_none());
    }

    /// Unbiasedness identity: plugging expectations under the noise model
    /// (Eq. 1) into Eqs. 4/5 recovers the true parameters for arbitrary
    /// batch sizes and parameter values.
    #[test]
    fn prop_estimators_invert_noise_model() {
        crate::util::prop::forall(
            11,
            500,
            |r| {
                (
                    10f64.powf(r.range_f64(-6.0, 6.0)), // g2
                    r.range_f64(0.0, 1e6),              // tr
                    r.range_f64(2.0, 4096.0),           // b_big
                )
            },
            |&(g2, tr, b_big)| {
                let big = g2 + tr / b_big;
                let small = g2 + tr;
                let c = gns_components(b_big, big, 1.0, small);
                crate::prop_check!(
                    (c.g_sq - g2).abs() <= 1e-9 * g2.max(tr).max(1.0),
                    "g_sq {} != {}", c.g_sq, g2
                );
                crate::prop_check!(
                    (c.s - tr).abs() <= 1e-9 * g2.max(tr).max(1.0),
                    "s {} != {}", c.s, tr
                );
                Ok(())
            },
        );
    }

    #[test]
    fn degenerate_batch_sizes_yield_nan_not_panic() {
        // b_big == b_small: Eqs. 4/5 are undefined (0/0).
        let c = gns_components(8.0, 1.0, 8.0, 1.0);
        assert!(c.g_sq.is_nan() && c.s.is_nan(), "{c:?}");
        assert_eq!(c.b_simple(), None);
        // b_big < b_small and b_small <= 0 likewise.
        assert!(gns_components(1.0, 1.0, 8.0, 1.0).g_sq.is_nan());
        assert!(gns_components(8.0, 1.0, 0.0, 1.0).s.is_nan());
        assert!(gns_components(8.0, 1.0, -1.0, 1.0).s.is_nan());
    }

    #[test]
    fn b_simple_guards_near_zero_g_sq() {
        assert_eq!(GnsComponents { g_sq: 0.0, s: 1.0 }.b_simple(), None);
        assert_eq!(GnsComponents { g_sq: 1e-301, s: 1.0 }.b_simple(), None);
        assert_eq!(GnsComponents { g_sq: f64::NAN, s: 1.0 }.b_simple(), None);
        let b = GnsComponents { g_sq: 2.0, s: 6.0 }.b_simple().unwrap();
        assert!((b - 3.0).abs() < 1e-12);
    }

    /// `finish()` against a brute-force reimplementation of Algorithm 1
    /// step 4 on random stats vectors, random microbatch sizes, and a
    /// random number of microbatches.
    #[test]
    fn prop_finish_matches_bruteforce_per_example_mean() {
        crate::util::prop::forall(
            13,
            300,
            |r| {
                let mb = r.range(1, 9);
                let k = r.range(1, 12);
                let stats: Vec<Vec<f32>> = (0..k)
                    .map(|_| (0..3).map(|_| r.range_f64(0.0, 10.0) as f32).collect())
                    .collect();
                (mb, stats)
            },
            |(mb, stats)| {
                let mut acc = GnsAccumulator::new(3, *mb);
                for s in stats {
                    acc.add_microbatch(s);
                }
                let (per_type, total) = acc.finish();
                // Brute force: sum_b ||dL_b||^2 = B^2 * raw, averaged over
                // all k*B examples.
                let b = *mb as f64;
                let n = (stats.len() * mb) as f64;
                for t in 0..3 {
                    let want: f64 =
                        stats.iter().map(|s| b * b * (s[t] as f64)).sum::<f64>() / n;
                    crate::prop_check!(
                        (per_type[t] - want).abs() <= 1e-9 * want.max(1.0),
                        "type {t}: {} != {want}",
                        per_type[t]
                    );
                }
                let want_total: f64 = per_type.iter().sum();
                crate::prop_check!(
                    (total - want_total).abs() <= 1e-9 * want_total.max(1.0),
                    "total {total} != {want_total}"
                );
                Ok(())
            },
        );
    }

    /// The accumulator's mean is invariant to microbatch ordering.
    #[test]
    fn prop_accumulator_mean_is_order_invariant() {
        crate::util::prop::forall(
            12,
            200,
            |r| crate::util::prop::vec_of(r, 4, |r| r.range_f64(0.0, 10.0) as f32),
            |stats| {
                let mut one = GnsAccumulator::new(1, 2);
                for s in stats {
                    one.add_microbatch(&[*s]);
                }
                let mut per2 = GnsAccumulator::new(1, 2);
                for s in stats.iter().rev() {
                    per2.add_microbatch(&[*s]);
                }
                let a = one.finish().1;
                let b = per2.finish().1;
                crate::prop_check!((a - b).abs() < 1e-6 * a.abs().max(1.0), "{a} != {b}");
                Ok(())
            },
        );
    }
}
