//! Ordinary least squares + Pearson correlation, for the Fig. 7 analysis:
//! regressing the total GNS against each layer type's GNS across EMA alphas.

#[derive(Debug, Clone, Copy)]
pub struct Regression {
    pub slope: f64,
    pub intercept: f64,
    /// Pearson correlation coefficient r.
    pub r: f64,
    pub n: usize,
}

/// OLS of y on x. Returns None for degenerate inputs (n < 2 or zero
/// variance in x).
pub fn linreg(x: &[f64], y: &[f64]) -> Option<Regression> {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    if n < 2 {
        return None;
    }
    let nf = n as f64;
    let mx = x.iter().sum::<f64>() / nf;
    let my = y.iter().sum::<f64>() / nf;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    let mut sxy = 0.0;
    for i in 0..n {
        let dx = x[i] - mx;
        let dy = y[i] - my;
        sxx += dx * dx;
        syy += dy * dy;
        sxy += dx * dy;
    }
    if sxx <= 0.0 {
        return None;
    }
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let r = if syy > 0.0 { sxy / (sxx * syy).sqrt() } else { 0.0 };
    Some(Regression { slope, intercept, r, n })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovered() {
        let x: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 1.4 * v + 0.3).collect();
        let r = linreg(&x, &y).unwrap();
        assert!((r.slope - 1.4).abs() < 1e-12);
        assert!((r.intercept - 0.3).abs() < 1e-12);
        assert!((r.r - 1.0).abs() < 1e-12);
    }

    #[test]
    fn anticorrelation() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| -2.0 * v).collect();
        let r = linreg(&x, &y).unwrap();
        assert!((r.r + 1.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs() {
        assert!(linreg(&[1.0], &[2.0]).is_none());
        assert!(linreg(&[3.0, 3.0, 3.0], &[1.0, 2.0, 3.0]).is_none());
    }

    /// r is always in [-1, 1]; slope sign matches r's sign.
    #[test]
    fn prop_r_bounded() {
        crate::util::prop::forall(
            41,
            300,
            |r| {
                let n = r.range(3, 50);
                crate::util::prop::vec_of(r, n, |r| {
                    (r.range_f64(-1e3, 1e3), r.range_f64(-1e3, 1e3))
                })
            },
            |pts| {
                let (x, y): (Vec<_>, Vec<_>) = pts.iter().cloned().unzip();
                if let Some(reg) = linreg(&x, &y) {
                    crate::prop_check!(
                        reg.r >= -1.0 - 1e-9 && reg.r <= 1.0 + 1e-9,
                        "r = {}", reg.r
                    );
                    if reg.r.abs() > 1e-9 {
                        crate::prop_check!(
                            reg.slope.signum() == reg.r.signum(),
                            "slope/r sign mismatch"
                        );
                    }
                }
                Ok(())
            },
        );
    }

    /// Affine-transforming x rescales the slope exactly.
    #[test]
    fn prop_slope_scales() {
        crate::util::prop::forall(
            42,
            300,
            |r| {
                let n = r.range(3, 30);
                let pts = crate::util::prop::vec_of(r, n, |r| {
                    (r.range_f64(-100.0, 100.0), r.range_f64(-100.0, 100.0))
                });
                (pts, r.range_f64(0.1, 10.0))
            },
            |(pts, a)| {
                let (x, y): (Vec<_>, Vec<_>) = pts.iter().cloned().unzip();
                if let (Some(r1), Some(r2)) = (
                    linreg(&x, &y),
                    linreg(&x.iter().map(|v| a * v).collect::<Vec<_>>(), &y),
                ) {
                    crate::prop_check!(
                        (r1.slope - a * r2.slope).abs() < 1e-6 * r1.slope.abs().max(1.0),
                        "slope scaling broken"
                    );
                }
                Ok(())
            },
        );
    }
}
