//! Synthetic gradient world with a *known* GNS, for validating the
//! estimators and regenerating Fig. 2 (estimator stderr vs B_small/B_big).
//!
//! Model (paper Eq. 1): per-example gradients are
//! `g_i ~ N(G, Sigma)` with isotropic `Sigma = (tr/d) I`. Then
//! `B_simple = tr(Sigma) / ||G||^2` exactly, and batch-B gradient norms
//! have `E||G_B||^2 = ||G||^2 + tr(Sigma)/B`.

use crate::util::rng::Rng;

use super::estimators::gns_components;
use super::jackknife::jackknife_ratio_stderr;

#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Parameter dimension of the synthetic gradient.
    pub dim: usize,
    /// True squared gradient norm ||G||^2.
    pub g_sq: f64,
    /// True gradient noise tr(Sigma).
    pub tr_sigma: f64,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        // GNS = 1 as in Fig. 2.
        Self { dim: 256, g_sq: 1.0, tr_sigma: 1.0, seed: 0 }
    }
}

pub struct GnsSimulator {
    cfg: SimConfig,
    g: Vec<f64>,
    sigma_per_dim: f64,
    rng: Rng,
}

impl GnsSimulator {
    pub fn new(cfg: SimConfig) -> Self {
        let mut rng = Rng::seed_from_u64(cfg.seed);
        // random direction with exact squared norm g_sq
        let mut g: Vec<f64> = (0..cfg.dim).map(|_| rng.normal()).collect();
        let norm: f64 = g.iter().map(|x| x * x).sum::<f64>().sqrt();
        let scale = cfg.g_sq.sqrt() / norm;
        for x in &mut g {
            *x *= scale;
        }
        Self { cfg, g, sigma_per_dim: cfg.tr_sigma / cfg.dim as f64, rng }
    }

    pub fn true_gns(&self) -> f64 {
        self.cfg.tr_sigma / self.cfg.g_sq
    }

    /// Squared norm of the mean gradient over a batch of `b` examples.
    ///
    /// mean of b i.i.d. N(G, sI) draws is N(G, (s/b) I); sample directly.
    pub fn batch_grad_sq_norm(&mut self, b: usize) -> f64 {
        let sd = (self.sigma_per_dim / b as f64).sqrt();
        self.g
            .iter()
            .map(|&gi| {
                let z: f64 = self.rng.normal();
                let v = gi + sd * z;
                v * v
            })
            .sum()
    }

    /// One optimizer-step observation: a big-batch norm plus the mean of
    /// `b_big / b_small` small-batch norms (the Microbatch taxonomy entry;
    /// `b_small = 1` is the per-example method).
    pub fn observe_step(&mut self, b_big: usize, b_small: usize) -> (f64, f64) {
        assert!(b_big % b_small == 0 && b_big > b_small);
        let n_small = b_big / b_small;
        let big = self.batch_grad_sq_norm(b_big);
        let small = (0..n_small).map(|_| self.batch_grad_sq_norm(b_small)).sum::<f64>()
            / n_small as f64;
        (big, small)
    }

    /// Run `steps` observations and return (gns_estimate, jackknife_stderr),
    /// reproducing one point of Fig. 2.
    pub fn estimate(&mut self, b_big: usize, b_small: usize, steps: usize) -> (f64, f64) {
        let mut s_obs = Vec::with_capacity(steps);
        let mut g_obs = Vec::with_capacity(steps);
        for _ in 0..steps {
            let (big, small) = self.observe_step(b_big, b_small);
            let c = gns_components(b_big as f64, big, b_small as f64, small);
            s_obs.push(c.s);
            g_obs.push(c.g_sq);
        }
        jackknife_ratio_stderr(&s_obs, &g_obs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_norm_expectation_matches_theory() {
        let mut sim = GnsSimulator::new(SimConfig { dim: 128, g_sq: 2.0, tr_sigma: 4.0, seed: 1 });
        let n = 4000;
        for b in [1usize, 8, 64] {
            let mean: f64 =
                (0..n).map(|_| sim.batch_grad_sq_norm(b)).sum::<f64>() / n as f64;
            let expect = 2.0 + 4.0 / b as f64;
            assert!(
                (mean - expect).abs() < 0.15 * expect,
                "b={b}: {mean} vs {expect}"
            );
        }
    }

    #[test]
    fn estimator_recovers_true_gns() {
        let mut sim = GnsSimulator::new(SimConfig::default());
        let (est, se) = sim.estimate(64, 1, 400);
        assert!(se > 0.0);
        assert!((est - 1.0).abs() < 5.0 * se.max(0.05), "est={est} se={se}");
    }

    #[test]
    fn smaller_b_small_has_lower_stderr() {
        // The paper's Fig. 2 (right) headline: for the same number of
        // samples processed, smaller B_small always wins. Average over
        // seeds to make the test robust.
        let avg_se = |b_small: usize| -> f64 {
            (0..8)
                .map(|seed| {
                    let mut sim = GnsSimulator::new(SimConfig {
                        seed,
                        ..SimConfig::default()
                    });
                    sim.estimate(64, b_small, 200).1
                })
                .sum::<f64>()
                / 8.0
        };
        let se1 = avg_se(1);
        let se16 = avg_se(16);
        assert!(se1 < se16, "se(B_small=1)={se1} !< se(B_small=16)={se16}");
    }

    #[test]
    fn b_big_does_not_matter_much() {
        // Fig. 2 (left): stderr is insensitive to B_big *at equal numbers
        // of samples processed* (steps scale inversely with B_big).
        let budget = 25_600usize;
        let avg_se = |b_big: usize| -> f64 {
            (0..8)
                .map(|seed| {
                    let mut sim = GnsSimulator::new(SimConfig {
                        seed: 100 + seed,
                        ..SimConfig::default()
                    });
                    sim.estimate(b_big, 1, budget / b_big).1
                })
                .sum::<f64>()
                / 8.0
        };
        let a = avg_se(16);
        let b = avg_se(256);
        let ratio = a / b;
        assert!(ratio > 0.4 && ratio < 2.5, "stderr ratio {ratio} not ~1");
    }
}
