//! End-to-end tests for the `repro serve` telemetry daemon (hermetic,
//! real sockets on loopback).
//!
//! These enforce PR 7's contracts:
//! * concurrent pollers walking `/records?since=` see every step exactly
//!   once, with monotone cursors and valid JSON, while training runs;
//! * `POST /shutdown` stops the run gracefully at a step boundary and
//!   parks a final checkpoint before the daemon exits;
//! * attaching the daemon — even under heavy poller traffic — leaves the
//!   run's metrics CSV byte-identical (modulo the wall-clock `step_ms`
//!   column) to the same run without a server.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use nanogns::config::TrainConfig;
use nanogns::coordinator::Trainer;
use nanogns::norms::{NormKind, NormPlacement};
use nanogns::runtime::ReferenceFactory;
use nanogns::serve::{self, HubMeta, RunState, Server, TelemetryHub};
use nanogns::util::json::Value;

fn temp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("nanogns_pr7_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Issue one raw HTTP request and return (status, body).
fn http(addr: SocketAddr, request: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(request.as_bytes()).unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let status: u16 = raw.split_whitespace().nth(1).unwrap().parse().unwrap();
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

fn get(addr: SocketAddr, path: &str) -> (u16, String) {
    http(addr, &format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n"))
}

fn post(addr: SocketAddr, path: &str) -> (u16, String) {
    http(addr, &format!("POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\r\n"))
}

/// Build trainer + hub + bound server (ephemeral port) and spawn the
/// accept loop. The trainer stays on the caller's thread.
fn boot(
    cfg: TrainConfig,
    ring: usize,
) -> (Trainer, Arc<TelemetryHub>, SocketAddr, thread::JoinHandle<anyhow::Result<()>>) {
    let tr = Trainer::new(&ReferenceFactory, cfg).unwrap();
    let hub = Arc::new(TelemetryHub::new(serve::hub_meta(&tr, std::path::Path::new(".")), ring));
    let server = Server::bind("127.0.0.1", 0, Arc::clone(&hub)).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = thread::spawn(move || server.serve());
    (tr, hub, addr, handle)
}

#[test]
fn concurrent_pollers_see_every_step_exactly_once() {
    const STEPS: u64 = 12;
    let cfg = TrainConfig::quickstart("nano", STEPS);
    let (mut tr, hub, addr, server) = boot(cfg, 64);

    // 4 clients poll the cursor API concurrently with training; each
    // must reconstruct the full, gap-free step sequence.
    let pollers: Vec<_> = (0..4)
        .map(|_| {
            thread::spawn(move || {
                let mut since = 0u64;
                let mut seen: Vec<u64> = Vec::new();
                loop {
                    let (code, body) = get(addr, &format!("/records?since={since}&limit=5"));
                    assert_eq!(code, 200, "{body}");
                    let v = Value::parse(&body).expect("records body is valid JSON");
                    let next = v.get("next_since").unwrap().as_u64().unwrap();
                    assert!(next >= since, "cursor went backwards: {next} < {since}");
                    let records = v.get("records").unwrap().as_arr().unwrap();
                    let mut prev = since;
                    for r in records {
                        let s = r.get("step").unwrap().as_u64().unwrap();
                        assert!(s > prev, "duplicate or out-of-order step {s} (cursor {prev})");
                        prev = s;
                        seen.push(s);
                    }
                    // `truncated` and `state` are part of the contract.
                    v.get("truncated").unwrap().as_bool().unwrap();
                    let state = v.get("state").unwrap().as_str().unwrap().to_string();
                    since = next;
                    if state != "running" && records.is_empty() {
                        return seen;
                    }
                    thread::sleep(Duration::from_millis(1));
                }
            })
        })
        .collect();

    let out = serve::train_and_publish(&mut tr, &hub).unwrap();
    assert_eq!(out.records.len(), STEPS as usize);

    for p in pollers {
        let seen = p.join().unwrap();
        assert_eq!(seen.len(), STEPS as usize, "poller missed records: {seen:?}");
        for w in seen.windows(2) {
            assert_eq!(w[1], w[0] + 1, "gap in step sequence: {seen:?}");
        }
        assert_eq!(*seen.last().unwrap(), out.records.last().unwrap().step);
    }

    // Natural finish keeps the daemon up until an explicit shutdown.
    let (code, body) = get(addr, "/status");
    assert_eq!(code, 200);
    let st = Value::parse(&body).unwrap();
    assert_eq!(st.get("state").unwrap().as_str().unwrap(), "finished");
    assert_eq!(st.get("last").unwrap().get("step").unwrap().as_u64().unwrap(), STEPS);
    assert_eq!(st.get("norm_kind").unwrap().as_str().unwrap(), "layernorm");
    assert_eq!(st.get("norm_placement").unwrap().as_str().unwrap(), "preln");

    // The live predictor endpoint reports the variant and (once the GNS
    // EMAs have warmed up and produced finite pairs) a fit window.
    let (code, body) = get(addr, "/gns/predictor");
    assert_eq!(code, 200);
    let pred = Value::parse(&body).unwrap();
    assert_eq!(pred.get("norm_kind").unwrap().as_str().unwrap(), "layernorm");
    assert_eq!(pred.get("norm_placement").unwrap().as_str().unwrap(), "preln");
    assert_eq!(pred.get("step").unwrap().as_u64().unwrap(), STEPS);
    pred.get("points").unwrap().as_u64().unwrap();
    pred.get("fit").unwrap(); // present (object or null), always valid JSON

    let (code, body) = post(addr, "/shutdown");
    assert_eq!(code, 200);
    let v = Value::parse(&body).unwrap();
    assert!(v.get("ok").unwrap().as_bool().unwrap());
    server.join().unwrap().unwrap();
}

#[test]
fn post_shutdown_stops_run_early_and_parks_checkpoint() {
    let dir = temp_dir("graceful");
    let mut cfg = TrainConfig::quickstart("nano", 500);
    cfg.checkpoint_dir = dir.to_string_lossy().into_owned();
    let (mut tr, hub, addr, server) = boot(cfg, 64);

    // Client thread: wait for training to make visible progress, then
    // ask the daemon to stop.
    let poster = thread::spawn(move || loop {
        let (code, body) = get(addr, "/health");
        assert_eq!(code, 200);
        let v = Value::parse(&body).unwrap();
        if v.get("step").unwrap().as_u64().unwrap() >= 2 {
            let (code, body) = post(addr, "/shutdown");
            assert_eq!(code, 200);
            let v = Value::parse(&body).unwrap();
            assert!(v.get("ok").unwrap().as_bool().unwrap());
            assert!(v.get("checkpointing").unwrap().as_bool().unwrap());
            return;
        }
        thread::sleep(Duration::from_millis(2));
    });

    let out = serve::train_and_publish(&mut tr, &hub).unwrap();
    poster.join().unwrap();
    server.join().unwrap().unwrap();

    assert_eq!(hub.run_state(), RunState::Stopped);
    assert!(
        (out.records.len() as u64) < 500,
        "run was supposed to stop early, did {} steps",
        out.records.len()
    );
    assert!((out.records.len() as u64) >= 2);
    // The graceful stop parked a resumable checkpoint.
    assert!(dir.join("latest.ckpt").is_file(), "no final checkpoint in {dir:?}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Strip the wall-clock `step_ms` column (located via the header) from
/// a metrics CSV so two runs can be compared bitwise.
fn strip_step_ms(csv: &str) -> String {
    let mut lines = csv.lines();
    let header = lines.next().expect("csv has a header");
    let drop_idx = header
        .split(',')
        .position(|c| c == "step_ms")
        .expect("header has step_ms");
    let mut out = String::new();
    for line in std::iter::once(header).chain(lines) {
        let kept: Vec<&str> = line
            .split(',')
            .enumerate()
            .filter(|(i, _)| *i != drop_idx)
            .map(|(_, c)| c)
            .collect();
        out.push_str(&kept.join(","));
        out.push('\n');
    }
    out
}

#[test]
fn metrics_csv_identical_under_32_poller_load() {
    const STEPS: u64 = 8;
    let dir = temp_dir("csv");
    let quiet_csv = dir.join("quiet.csv");
    let served_csv = dir.join("served.csv");

    // Reference run: no daemon attached.
    let mut cfg = TrainConfig::quickstart("nano", STEPS);
    cfg.metrics_path = quiet_csv.to_string_lossy().into_owned();
    let mut tr = Trainer::new(&ReferenceFactory, cfg).unwrap();
    tr.run().unwrap();

    // Served run: identical config, 32 clients hammering every endpoint.
    let mut cfg = TrainConfig::quickstart("nano", STEPS);
    cfg.metrics_path = served_csv.to_string_lossy().into_owned();
    let (mut tr, hub, addr, server) = boot(cfg, 64);
    const PATHS: [&str; 7] = [
        "/records?since=0",
        "/status",
        "/gns/layers",
        "/gns/predictor",
        "/metrics",
        "/schedule",
        "/health",
    ];
    let stop = Arc::new(AtomicBool::new(false));
    let pollers: Vec<_> = (0..32usize)
        .map(|i| {
            let stop = Arc::clone(&stop);
            thread::spawn(move || {
                let mut n = 0usize;
                while !stop.load(Ordering::Acquire) {
                    let (code, _body) = get(addr, PATHS[(i + n) % PATHS.len()]);
                    assert_eq!(code, 200);
                    n += 1;
                }
                n
            })
        })
        .collect();

    serve::train_and_publish(&mut tr, &hub).unwrap();
    stop.store(true, Ordering::Release);
    let total: usize = pollers.into_iter().map(|p| p.join().unwrap()).sum();
    assert!(total > 0, "pollers served no requests");
    hub.request_shutdown();
    server.join().unwrap().unwrap();

    let quiet = std::fs::read_to_string(&quiet_csv).unwrap();
    let served = std::fs::read_to_string(&served_csv).unwrap();
    assert_eq!(
        strip_step_ms(&quiet),
        strip_step_ms(&served),
        "serving telemetry perturbed the run's CSV"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn router_rejects_unknown_paths_methods_and_bad_queries() {
    // A bare hub (no trainer) is enough to exercise the router edges.
    let hub = Arc::new(TelemetryHub::new(
        HubMeta {
            model: "nano".into(),
            platform: "test".into(),
            norm_kind: NormKind::default(),
            norm_placement: NormPlacement::default(),
            total_steps: 1,
            n_params: 1,
            ranks: 1,
            microbatch: 1,
            schedule: Value::Null,
            checkpoint_dir: String::new(),
            metrics_path: String::new(),
            bench: None,
        },
        8,
    ));
    let server = Server::bind("127.0.0.1", 0, Arc::clone(&hub)).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = thread::spawn(move || server.serve());

    let (code, body) = get(addr, "/nope");
    assert_eq!(code, 404);
    assert!(Value::parse(&body).unwrap().get("error").is_ok());
    let (code, _) = get(addr, "/shutdown");
    assert_eq!(code, 405);
    let (code, body) = get(addr, "/records?since=abc");
    assert_eq!(code, 400, "{body}");
    let (code, _) = post(addr, "/status");
    assert_eq!(code, 405);
    let (code, _) = get(addr, "/health");
    assert_eq!(code, 200);

    hub.mark_done(RunState::Stopped, None, None);
    let (code, _) = post(addr, "/shutdown");
    assert_eq!(code, 200);
    handle.join().unwrap().unwrap();
}
