//! Steady-state allocation contract for the persistent worker pool:
//! after warmup, dispatching parallel regions through `WorkerPool::run`
//! and `par_row_blocks` performs **zero** heap allocations — the pool
//! publishes each job as a raw borrow into a pre-existing slot, and the
//! row-block partitioner hands workers disjoint sub-slices of caller
//! buffers.
//!
//! This binary holds exactly one test: the counting allocator is
//! process-global, so any concurrently running test would pollute the
//! measurement. Keep it that way.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use nanogns::runtime::kernels::{par_row_blocks, WorkerPool};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn pool_dispatch_is_allocation_free_after_warmup() {
    let pool = WorkerPool::new(4);
    let rows = 64usize;
    let row_len = 32usize;
    let mut buf = vec![0f32; rows * row_len];

    let work = |r0: usize, _r1: usize, block: &mut [f32]| {
        for (i, v) in block.iter_mut().enumerate() {
            *v = (r0 * row_len + i) as f32;
        }
    };

    // Warmup: faults in lazy init everywhere (tier detection env reads,
    // thread parking structures, panic machinery bookkeeping).
    for _ in 0..5 {
        par_row_blocks(&pool, rows, row_len, &mut buf, work);
        pool.run(16, &|_ti| {});
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..100 {
        par_row_blocks(&pool, rows, row_len, &mut buf, work);
        pool.run(16, &|_ti| {});
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state pool dispatch must not allocate ({} allocs in 200 dispatches)",
        after - before
    );

    // The work actually ran: last write wins deterministically.
    assert_eq!(buf[0], 0.0);
    assert_eq!(buf[rows * row_len - 1], (rows * row_len - 1) as f32);
}
