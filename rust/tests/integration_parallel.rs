//! Rank-parallel + checkpoint/resume integration tests (hermetic).
//!
//! These enforce PR 5's two contracts end-to-end:
//! * the rank-parallel engine is **bitwise identical** to sequential
//!   execution for any worker count (the CI determinism matrix re-runs
//!   this suite across `NANOGNS_THREADS` × `NANOGNS_RANK_WORKERS`);
//! * a run checkpointed at step k and resumed in a fresh `Trainer`
//!   reproduces the uninterrupted trajectory exactly, and corrupted
//!   checkpoints are rejected instead of silently mis-restoring.

use nanogns::config::TrainConfig;
use nanogns::coordinator::trainer::StepRecord;
use nanogns::coordinator::{checkpoint, Trainer};
use nanogns::runtime::{BackendFactory, ReferenceFactory};
use nanogns::schedule::{BatchSizeSchedule, LrSchedule};
use nanogns::N_TYPES;

/// A config that exercises every piece of resumable state: multiple
/// ranks (loader cursors), a ramping schedule (controller hysteresis),
/// and EMA smoothing (tracker state).
fn multi_rank_cfg(steps: u64, ranks: usize) -> TrainConfig {
    let mut cfg = TrainConfig::quickstart("nano", steps);
    cfg.ranks = ranks;
    cfg.lr = LrSchedule { max_lr: 3e-3, min_lr: 3e-4, warmup_steps: 2, decay_steps: steps };
    let tpa = {
        let e = ReferenceFactory.describe("nano").unwrap();
        (e.microbatch * e.seq_len) as u64
    };
    cfg.batch_size = BatchSizeSchedule::Linear {
        min_accum: 1,
        max_accum: 3,
        ramp_tokens: steps * tpa,
    };
    cfg
}

fn bits(x: f64) -> u64 {
    x.to_bits()
}

/// Bitwise record equality, `step_ms` excluded (wall clock).
fn assert_records_eq(a: &StepRecord, b: &StepRecord, ctx: &str) {
    assert_eq!(a.step, b.step, "{ctx}: step");
    assert_eq!(a.tokens, b.tokens, "{ctx}: tokens");
    assert_eq!(a.accum, b.accum, "{ctx}: accum");
    assert_eq!(bits(a.loss), bits(b.loss), "{ctx}: loss {} vs {}", a.loss, b.loss);
    assert_eq!(bits(a.lr), bits(b.lr), "{ctx}: lr");
    assert_eq!(bits(a.b_big), bits(b.b_big), "{ctx}: b_big");
    for t in 0..N_TYPES {
        assert_eq!(bits(a.raw_g_sq[t]), bits(b.raw_g_sq[t]), "{ctx}: raw_g_sq[{t}]");
        assert_eq!(bits(a.raw_s[t]), bits(b.raw_s[t]), "{ctx}: raw_s[{t}]");
    }
    assert_eq!(bits(a.raw_g_sq_total), bits(b.raw_g_sq_total), "{ctx}: raw_g_sq_total");
    assert_eq!(bits(a.raw_s_total), bits(b.raw_s_total), "{ctx}: raw_s_total");
    assert_eq!(bits(a.gns_layernorm), bits(b.gns_layernorm), "{ctx}: gns_layernorm");
    assert_eq!(bits(a.gns_total), bits(b.gns_total), "{ctx}: gns_total");
}

fn run_steps(tr: &mut Trainer, n: usize) -> Vec<StepRecord> {
    (0..n).map(|_| tr.step().unwrap()).collect()
}

fn temp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("nanogns_pr5_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The tentpole property: the whole training trajectory — loss, GNS
/// components, schedule decisions — is bitwise identical for any
/// rank-worker count, odd and even rank counts alike.
#[test]
fn trainer_trajectory_is_bitwise_invariant_to_rank_workers() {
    for ranks in [3usize, 4] {
        let mut reference: Option<Vec<StepRecord>> = None;
        for workers in [1usize, 2, ranks] {
            let cfg = multi_rank_cfg(4, ranks);
            let mut tr = Trainer::with_rank_workers(&ReferenceFactory, cfg, workers).unwrap();
            let records = run_steps(&mut tr, 4);
            match &reference {
                None => reference = Some(records),
                Some(want) => {
                    for (a, b) in records.iter().zip(want) {
                        let ctx = format!("ranks={ranks} workers={workers} step={}", b.step);
                        assert_records_eq(a, b, &ctx);
                    }
                }
            }
        }
    }
}

/// The env-default engine (whatever `NANOGNS_RANK_WORKERS` the CI matrix
/// sets) must agree with explicit single-worker execution.
#[test]
fn default_worker_engine_matches_explicit_single_worker() {
    let mut seq = Trainer::with_rank_workers(&ReferenceFactory, multi_rank_cfg(3, 4), 1).unwrap();
    let mut env = Trainer::new(&ReferenceFactory, multi_rank_cfg(3, 4)).unwrap();
    let a = run_steps(&mut seq, 3);
    let b = run_steps(&mut env, 3);
    for (x, y) in a.iter().zip(&b) {
        assert_records_eq(x, y, &format!("env-default workers={}", env.rank_workers()));
    }
}

/// Train k steps, checkpoint, resume in a fresh Trainer: the next M
/// records must be bitwise equal to the uninterrupted run's.
#[test]
fn checkpoint_resume_reproduces_trajectory_bitwise() {
    let dir = temp_dir("resume");
    let path = dir.join("mid.ckpt");

    let mut full = Trainer::new(&ReferenceFactory, multi_rank_cfg(7, 2)).unwrap();
    let all = run_steps(&mut full, 7);

    let mut head = Trainer::new(&ReferenceFactory, multi_rank_cfg(7, 2)).unwrap();
    let head_records = run_steps(&mut head, 4);
    for (a, b) in head_records.iter().zip(&all) {
        assert_records_eq(a, b, "pre-checkpoint divergence (test bug)");
    }
    head.save_checkpoint(&path).unwrap();
    drop(head);

    let mut resumed = Trainer::resume(&ReferenceFactory, multi_rank_cfg(7, 2), &path).unwrap();
    assert_eq!(resumed.runner.step, 4);
    let tail = run_steps(&mut resumed, 3);
    for (a, b) in tail.iter().zip(&all[4..]) {
        assert_records_eq(a, b, &format!("resumed step {}", b.step));
    }
}

/// `run()` with checkpointing enabled writes periodic checkpoints plus
/// `latest.ckpt`, and a resumed `run()` finishes exactly the remaining
/// step budget with the uninterrupted trajectory.
#[test]
fn run_writes_checkpoints_and_resumes_remaining_budget() {
    let dir = temp_dir("run_ckpt");
    let mut cfg = multi_rank_cfg(6, 2);
    cfg.checkpoint_dir = dir.to_string_lossy().into_owned();
    cfg.checkpoint_every = 2;

    let mut full = Trainer::new(&ReferenceFactory, cfg.clone()).unwrap();
    let out = full.run().unwrap();
    assert_eq!(out.records.len(), 6);
    for step in [2u64, 4, 6] {
        assert!(dir.join(format!("step-{step:08}.ckpt")).exists(), "missing step {step}");
    }
    assert!(dir.join("latest.ckpt").exists());

    let ckpt = dir.join("step-00000004.ckpt");
    let mut resumed = Trainer::resume(&ReferenceFactory, cfg, &ckpt).unwrap();
    let tail = resumed.run().unwrap();
    assert_eq!(tail.records.len(), 2, "resume must run only the remaining steps");
    for (a, b) in tail.records.iter().zip(&out.records[4..]) {
        assert_records_eq(a, b, &format!("resumed run() step {}", b.step));
    }
    assert_eq!(resumed.tokens(), full.tokens());
}

/// Corrupted or mismatched checkpoints must be rejected with an error,
/// never silently mis-restored.
#[test]
fn corrupted_checkpoints_are_rejected() {
    let dir = temp_dir("corrupt");
    let good = dir.join("good.ckpt");
    let mut tr = Trainer::new(&ReferenceFactory, multi_rank_cfg(4, 2)).unwrap();
    run_steps(&mut tr, 2);
    tr.save_checkpoint(&good).unwrap();
    let entry = ReferenceFactory.describe("nano").unwrap();
    let blob = std::fs::read(&good).unwrap();

    // truncated payload
    let truncated = dir.join("truncated.ckpt");
    std::fs::write(&truncated, &blob[..blob.len() - 64]).unwrap();
    let err = checkpoint::load_state(&truncated, &entry).unwrap_err();
    assert!(format!("{err}").contains("truncated"), "{err}");

    // bad magic
    let bad_magic = dir.join("bad_magic.ckpt");
    let mut b = blob.clone();
    b[0] ^= 0xff;
    std::fs::write(&bad_magic, &b).unwrap();
    assert!(checkpoint::load_state(&bad_magic, &entry).is_err());

    // garbage header bytes
    let bad_header = dir.join("bad_header.ckpt");
    let mut b = blob.clone();
    for byte in b.iter_mut().skip(12).take(16) {
        *byte = 0xfe;
    }
    std::fs::write(&bad_header, &b).unwrap();
    assert!(checkpoint::load_state(&bad_header, &entry).is_err());

    // trailing junk after the payload
    let trailing = dir.join("trailing.ckpt");
    let mut b = blob.clone();
    b.extend_from_slice(&[0u8; 8]);
    std::fs::write(&trailing, &b).unwrap();
    let err = checkpoint::load_state(&trailing, &entry).unwrap_err();
    assert!(format!("{err}").contains("trailing"), "{err}");

    // a v1 (params-only) file is not a resumable checkpoint
    let v1 = dir.join("params_only.ckpt");
    checkpoint::save(&v1, &tr.runner.entry, &tr.runner.params).unwrap();
    let err = checkpoint::load_state(&v1, &entry).unwrap_err();
    assert!(format!("{err}").contains("v1"), "{err}");

    // model mismatch: a nano checkpoint cannot resume a micro config
    let mut cfg = multi_rank_cfg(4, 2);
    cfg.model = "micro".into();
    assert!(Trainer::resume(&ReferenceFactory, cfg, &good).is_err());

    // rank-count mismatch: 3-rank config vs 2-rank checkpoint
    let cfg3 = multi_rank_cfg(4, 3);
    assert!(Trainer::resume(&ReferenceFactory, cfg3, &good).is_err());

    // seed mismatch: a different corpus/loader stream must be rejected,
    // not silently forked
    let mut cfg_seed = multi_rank_cfg(4, 2);
    cfg_seed.seed += 1;
    let err = Trainer::resume(&ReferenceFactory, cfg_seed, &good).unwrap_err();
    assert!(format!("{err}").contains("seed"), "{err}");

    // the intact file still loads
    assert!(checkpoint::load_state(&good, &entry).is_ok());
}

/// A full-state checkpoint round-trips every scalar exactly (spot-check
/// via the public load path on a trainer that has NaN-free state).
#[test]
fn checkpoint_state_round_trip_is_exact() {
    let dir = temp_dir("exact");
    let path = dir.join("state.ckpt");
    let mut tr = Trainer::new(&ReferenceFactory, multi_rank_cfg(5, 2)).unwrap();
    run_steps(&mut tr, 3);
    tr.lr_scale = 1.25;
    tr.save_checkpoint(&path).unwrap();
    let entry = ReferenceFactory.describe("nano").unwrap();
    let st = checkpoint::load_state(&path, &entry).unwrap();
    assert_eq!(st.model, "nano");
    assert_eq!(st.seed, tr.cfg.seed);
    assert_eq!(st.corpus_bytes, tr.cfg.corpus_bytes as u64);
    assert_eq!(st.step, 3);
    assert_eq!(st.tokens, tr.tokens());
    assert_eq!(st.lr_scale.to_bits(), 1.25f64.to_bits());
    assert_eq!(st.loaders.len(), 2);
    assert_eq!(st.tracker, tr.tracker.export_state());
    let (m, _v) = tr.runner.moments();
    for (a, b) in st.params.iter().zip(&tr.runner.params) {
        assert_eq!(a.to_tensor().unwrap(), b.to_tensor().unwrap());
    }
    for (a, b) in st.m.iter().zip(m) {
        assert_eq!(a.to_tensor().unwrap(), b.to_tensor().unwrap());
    }
}
