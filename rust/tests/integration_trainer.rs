//! Trainer-level integration tests on the reference backend (hermetic).

use nanogns::config::TrainConfig;
use nanogns::coordinator::{ddp, ModelRunner, ParallelExecutor, Trainer};
use nanogns::data::{CorpusGenerator, Loader};
use nanogns::runtime::{BackendFactory, ReferenceFactory};
use nanogns::schedule::{BatchSizeSchedule, LrSchedule};

fn quick_cfg(steps: u64) -> TrainConfig {
    let mut cfg = TrainConfig::quickstart("nano", steps);
    cfg.lr = LrSchedule { max_lr: 3e-3, min_lr: 3e-4, warmup_steps: 5, decay_steps: steps };
    cfg
}

#[test]
fn loss_decreases_over_short_run() {
    let mut tr = Trainer::new(&ReferenceFactory, quick_cfg(40)).unwrap();
    let out = tr.run().unwrap();
    let first = out.records.first().unwrap().loss;
    let last = out.records.last().unwrap().loss;
    assert!(last < first - 0.25, "loss {first} -> {last}");
    assert_eq!(out.records.len(), 40);
}

#[test]
fn gns_estimates_become_finite() {
    let mut cfg = quick_cfg(10);
    cfg.gns_alpha = 0.3;
    let mut tr = Trainer::new(&ReferenceFactory, cfg).unwrap();
    tr.run().unwrap();
    let snap = tr.tracker.snapshot();
    // the dominant smoothed squared-norm component must be positive, and
    // every per-type component finite and actually populated (a stats
    // pathway that silently zeroes a layer type would leave exactly 0.0)
    assert!(snap.total.g_sq > 0.0, "{snap:?}");
    for (t, s) in &snap.per_type {
        assert!(s.g_sq.is_finite() && s.s.is_finite(), "{t}: {s:?}");
        assert!(s.g_sq != 0.0, "{t}: g_sq never populated: {s:?}");
    }
    assert!(tr.tracker.gns_total().is_some());
}

#[test]
fn accumulation_steps_follow_linear_schedule() {
    let mut cfg = quick_cfg(12);
    let tpa = {
        let e = ReferenceFactory.describe("nano").unwrap();
        (e.microbatch * e.seq_len) as u64
    };
    cfg.batch_size =
        BatchSizeSchedule::Linear { min_accum: 1, max_accum: 4, ramp_tokens: 12 * tpa };
    let mut tr = Trainer::new(&ReferenceFactory, cfg).unwrap();
    let out = tr.run().unwrap();
    let accums: Vec<usize> = out.records.iter().map(|r| r.accum).collect();
    assert_eq!(accums[0], 1);
    assert!(accums.windows(2).all(|w| w[1] >= w[0]), "{accums:?}");
    assert!(*accums.last().unwrap() >= 3, "{accums:?}");
}

#[test]
fn snapshot_restore_resumes_identically() {
    let mut tr = Trainer::new(&ReferenceFactory, quick_cfg(4)).unwrap();
    for _ in 0..2 {
        tr.step().unwrap();
    }
    let snap = tr.snapshot();
    let a = tr.step().unwrap();
    tr.restore(snap);
    let b = tr.step().unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(a.raw_g_sq_total, b.raw_g_sq_total);
}

#[test]
fn bigger_effective_batch_keeps_statistics_finite() {
    // E[mean per-example norm] is invariant to accumulation structure;
    // check the schedule machinery at two fixed batch sizes.
    let mut cfg = quick_cfg(1);
    cfg.batch_size = BatchSizeSchedule::Fixed { accum: 1 };
    let mut tr1 = Trainer::new(&ReferenceFactory, cfg.clone()).unwrap();
    let r1 = tr1.step().unwrap();
    cfg.batch_size = BatchSizeSchedule::Fixed { accum: 4 };
    // controller hysteresis: allow it to ramp over a few steps
    let mut tr4 = Trainer::new(&ReferenceFactory, cfg).unwrap();
    let mut r4 = tr4.step().unwrap();
    for _ in 0..4 {
        r4 = tr4.step().unwrap();
    }
    assert!(r4.b_big > r1.b_big);
    assert!(r4.raw_g_sq_total.is_finite());
    assert!(r1.raw_s_total.is_finite());
}

#[test]
fn ddp_estimator_agrees_with_per_example_in_scale() {
    let factory = ReferenceFactory;
    let mut runner = ModelRunner::new(&factory, "nano").unwrap();
    runner.init(9).unwrap();
    let entry = runner.entry.clone();
    let engine = ParallelExecutor::new(&factory, "nano", 4).unwrap();
    let text = CorpusGenerator::new(9).generate(1 << 16);
    let base = Loader::new(&text, entry.seq_len, 9);
    let mut loaders: Vec<Loader> = (0..4u64).map(|r| base.for_rank(r)).collect();
    // average several observations of both estimators at the same params
    let mut ddp_g = 0.0;
    let mut pex_g = 0.0;
    let n = 8;
    let accum = 2usize;
    for _ in 0..n {
        let mut acc = nanogns::gns::GnsAccumulator::new(nanogns::N_TYPES, entry.microbatch);
        let obs =
            ddp::ddp_step_with_stats(&engine, &runner.params, &mut loaders, accum, &mut acc)
                .unwrap();
        ddp_g += obs.total.g_sq / n as f64;
        // per-example estimator on the same gradients
        let sums = runner.grad_sqnorms(&obs.mean_grads).unwrap();
        let n_micro = (4 * accum) as f64;
        let big: f64 = sums.iter().map(|s| s / (n_micro * n_micro)).sum();
        let (_, small_tot) = acc.finish();
        let c = nanogns::gns::gns_components(obs.b_big, big, 1.0, small_tot);
        pex_g += c.g_sq / n as f64;
    }
    // Both estimate ||G||^2 from identical sampled gradients: they must
    // agree in scale at this (low) noise level.
    assert!(ddp_g.is_finite() && pex_g.is_finite());
    let ratio = ddp_g / pex_g;
    assert!(ratio > 0.25 && ratio < 4.0, "ddp {ddp_g} vs perex {pex_g}");
}

/// The runner's gradient arena is pure scratch: poisoning it between
/// steps (lease → overwrite → recycle) must not change training results.
#[test]
fn arena_reuse_does_not_change_training() {
    let mut clean = Trainer::new(&ReferenceFactory, quick_cfg(4)).unwrap();
    let mut dirty = Trainer::new(&ReferenceFactory, quick_cfg(4)).unwrap();
    for _ in 0..4 {
        // poison the dirty trainer's arena before every step
        let mut set = dirty.runner.lease_zero_grads().unwrap();
        for b in set.iter_mut() {
            let mut t = b.to_tensor().unwrap();
            t.data.fill(1e9);
            *b = nanogns::runtime::Buffer::Host(t);
        }
        dirty.runner.recycle_grads(set);
        let a = clean.step().unwrap();
        let b = dirty.step().unwrap();
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.raw_g_sq_total, b.raw_g_sq_total);
        assert_eq!(a.raw_s_total, b.raw_s_total);
    }
}

#[test]
fn eval_uses_heldout_stream() {
    let mut tr = Trainer::new(&ReferenceFactory, quick_cfg(4)).unwrap();
    tr.step().unwrap();
    let snap = tr.snapshot();
    // each eval() call reconstructs the same held-out stream: repeated
    // calls at fixed params are bitwise identical
    let e1 = tr.eval(2).unwrap();
    let e2 = tr.eval(2).unwrap();
    assert_eq!(e1, e2);
    assert!(e1.is_finite() && e1 > 0.0, "{e1}");
    // and eval consumes nothing from the training loaders: a step taken
    // after two evals matches a step taken with no evals in between
    let with_evals = tr.step().unwrap();
    tr.restore(snap);
    let without_evals = tr.step().unwrap();
    assert_eq!(with_evals.loss, without_evals.loss);
}
