//! Trainer-level integration tests (need `make artifacts`).

use nanogns::config::TrainConfig;
use nanogns::coordinator::{ddp, ModelRunner, Trainer};
use nanogns::data::{CorpusGenerator, Loader};
use nanogns::runtime::{Manifest, Runtime};
use nanogns::schedule::BatchSizeSchedule;

fn setup() -> Option<(Runtime, Manifest)> {
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping trainer integration tests: {e}");
            return None;
        }
    };
    Some((Runtime::cpu().expect("pjrt cpu"), manifest))
}

#[test]
fn loss_decreases_over_short_run() {
    let Some((rt, manifest)) = setup() else { return };
    let cfg = TrainConfig::quickstart("nano", 15);
    let mut tr = Trainer::new(&rt, &manifest, cfg).unwrap();
    let out = tr.run().unwrap();
    let first = out.records.first().unwrap().loss;
    let last = out.records.last().unwrap().loss;
    assert!(last < first - 0.3, "loss {first} -> {last}");
    assert_eq!(out.records.len(), 15);
}

#[test]
fn gns_estimates_become_finite_and_positive() {
    let Some((rt, manifest)) = setup() else { return };
    let mut cfg = TrainConfig::quickstart("nano", 10);
    cfg.gns_alpha = 0.3;
    let mut tr = Trainer::new(&rt, &manifest, cfg).unwrap();
    tr.run().unwrap();
    let snap = tr.tracker.snapshot();
    // smoothed squared-norm components must be positive
    assert!(snap.total.g_sq > 0.0, "{snap:?}");
    for (t, s) in &snap.per_type {
        assert!(s.g_sq > 0.0, "{t}: {s:?}");
    }
    assert!(tr.tracker.gns_total().is_some());
}

#[test]
fn accumulation_steps_follow_linear_schedule() {
    let Some((rt, manifest)) = setup() else { return };
    let mut cfg = TrainConfig::quickstart("nano", 12);
    let tpa = {
        let e = manifest.config("nano").unwrap();
        (e.microbatch * e.seq_len) as u64
    };
    cfg.batch_size = BatchSizeSchedule::Linear { min_accum: 1, max_accum: 4, ramp_tokens: 12 * tpa };
    let mut tr = Trainer::new(&rt, &manifest, cfg).unwrap();
    let out = tr.run().unwrap();
    let accums: Vec<usize> = out.records.iter().map(|r| r.accum).collect();
    assert_eq!(accums[0], 1);
    assert!(accums.windows(2).all(|w| w[1] >= w[0]), "{accums:?}");
    assert!(*accums.last().unwrap() >= 3, "{accums:?}");
}

#[test]
fn snapshot_restore_resumes_identically() {
    let Some((rt, manifest)) = setup() else { return };
    let cfg = TrainConfig::quickstart("nano", 4);
    let mut tr = Trainer::new(&rt, &manifest, cfg).unwrap();
    for _ in 0..2 {
        tr.step().unwrap();
    }
    let snap = tr.snapshot();
    let a = tr.step().unwrap();
    tr.restore(snap);
    let b = tr.step().unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(a.raw_g_sq_total, b.raw_g_sq_total);
}

#[test]
fn microbatch_accumulation_matches_bigger_effective_batch_statistics() {
    // E[mean per-example norm] is invariant to accumulation structure;
    // check the accumulated-gradient norm shrinks with batch (noise
    // averaging) while per-example stats stay on the same scale.
    let Some((rt, manifest)) = setup() else { return };
    let mut cfg = TrainConfig::quickstart("nano", 1);
    cfg.batch_size = BatchSizeSchedule::Fixed { accum: 1 };
    let mut tr1 = Trainer::new(&rt, &manifest, cfg.clone()).unwrap();
    let r1 = tr1.step().unwrap();
    cfg.batch_size = BatchSizeSchedule::Fixed { accum: 4 };
    // controller hysteresis: allow it to ramp over a few steps
    let mut tr4 = Trainer::new(&rt, &manifest, cfg).unwrap();
    let mut r4 = tr4.step().unwrap();
    for _ in 0..4 {
        r4 = tr4.step().unwrap();
    }
    assert!(r4.b_big > r1.b_big);
    // with more averaging the big-batch gradient norm estimate is smaller
    // than the per-example mean norm (strictly, in expectation)
    assert!(r4.raw_g_sq_total.is_finite());
}

#[test]
fn ddp_estimator_agrees_with_per_example_in_scale() {
    let Some((rt, manifest)) = setup() else { return };
    let mut runner = ModelRunner::new(&rt, &manifest, "nano").unwrap();
    runner.init(9).unwrap();
    let entry = manifest.config("nano").unwrap().clone();
    let text = CorpusGenerator::new(9).generate(1 << 16);
    let base = Loader::new(&text, entry.seq_len, 9);
    let mut loaders: Vec<Loader> = (0..4u64).map(|r| base.for_rank(r)).collect();
    // average several observations of both estimators at the same params
    let mut ddp_g = 0.0;
    let mut pex_g = 0.0;
    let n = 6;
    for _ in 0..n {
        let mut acc = nanogns::gns::GnsAccumulator::new(nanogns::N_TYPES, entry.microbatch);
        let obs = ddp::ddp_step_with_stats(&runner, &mut loaders, 1, &mut acc).unwrap();
        ddp_g += obs.total.g_sq / n as f64;
        // per-example estimator on the same gradients
        let sums = runner.grad_sqnorms(&obs.mean_grads).unwrap();
        let n_micro = 4.0;
        let big: f64 = sums.iter().map(|s| s / (n_micro * n_micro)).sum();
        let (small, small_tot) = acc.finish();
        let _ = small;
        let c = nanogns::gns::gns_components(obs.b_big, big, 1.0, small_tot);
        pex_g += c.g_sq / n as f64;
    }
    // Both estimate ||G||^2: must agree within a factor ~2 at this noise level
    let ratio = ddp_g / pex_g;
    assert!(ratio > 0.3 && ratio < 3.0, "ddp {ddp_g} vs perex {pex_g}");
}
