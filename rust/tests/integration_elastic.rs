//! Elastic process-mode integration tests (PR 8).
//!
//! Contracts enforced end-to-end, per DESIGN.md's elastic rank protocol
//! section:
//! * process mode (rank workers as supervised child processes) is
//!   **bitwise identical** to thread mode at the same rank count, for
//!   any worker count;
//! * `kill -9` on a rank worker mid-run does not abort the run: the
//!   coordinator reconciles (drops the dead positions, retries the step)
//!   and the survivors' trajectory is bitwise identical to a thread-mode
//!   run at the reduced rank count;
//! * a killed worker is respawned and re-admitted at a step boundary,
//!   after which the trajectory is bitwise identical to a run that
//!   dropped and readmitted the same rank at the same boundaries;
//! * async (writer-thread) checkpoints are byte-identical to synchronous
//!   ones, and a crash mid-`.tmp`-write leaves a resumable run behind.
//!
//! The child processes run this workspace's own `repro` binary
//! (`CARGO_BIN_EXE_repro`) through the hidden `rank-worker` subcommand.

use std::sync::atomic::{AtomicBool, Ordering};

use nanogns::config::{RankMode, TrainConfig};
use nanogns::coordinator::trainer::{StepObservation, StepObserver, StepRecord};
use nanogns::coordinator::{checkpoint, Trainer};
use nanogns::runtime::{BackendFactory, ReferenceFactory};
use nanogns::schedule::{BatchSizeSchedule, LrSchedule};
use nanogns::N_TYPES;

/// A config exercising every piece of elastic-sensitive state: several
/// ranks (per-rank loader cursors), a ramping batch-size schedule
/// (controller hysteresis that must rewind on a failed attempt), and a
/// warmup/decay LR schedule.
fn base_cfg(steps: u64, ranks: usize) -> TrainConfig {
    let mut cfg = TrainConfig::quickstart("nano", steps);
    cfg.ranks = ranks;
    cfg.lr = LrSchedule { max_lr: 3e-3, min_lr: 3e-4, warmup_steps: 2, decay_steps: steps };
    let tpa = {
        let e = ReferenceFactory.describe("nano").unwrap();
        (e.microbatch * e.seq_len) as u64
    };
    cfg.batch_size =
        BatchSizeSchedule::Linear { min_accum: 1, max_accum: 3, ramp_tokens: steps * tpa };
    cfg
}

/// `base_cfg` in elastic process mode, with the rank-worker children
/// spawned from this workspace's freshly built `repro` binary.
fn elastic_cfg(steps: u64, ranks: usize) -> TrainConfig {
    let mut cfg = base_cfg(steps, ranks);
    cfg.rank_mode = RankMode::Process;
    cfg.elastic.worker_exe = env!("CARGO_BIN_EXE_repro").to_string();
    cfg
}

fn bits(x: f64) -> u64 {
    x.to_bits()
}

/// Bitwise record equality, `step_ms` excluded (wall clock).
fn assert_records_eq(a: &StepRecord, b: &StepRecord, ctx: &str) {
    assert_eq!(a.step, b.step, "{ctx}: step");
    assert_eq!(a.tokens, b.tokens, "{ctx}: tokens");
    assert_eq!(a.accum, b.accum, "{ctx}: accum");
    assert_eq!(bits(a.loss), bits(b.loss), "{ctx}: loss {} vs {}", a.loss, b.loss);
    assert_eq!(bits(a.lr), bits(b.lr), "{ctx}: lr");
    assert_eq!(bits(a.b_big), bits(b.b_big), "{ctx}: b_big");
    for t in 0..N_TYPES {
        assert_eq!(bits(a.raw_g_sq[t]), bits(b.raw_g_sq[t]), "{ctx}: raw_g_sq[{t}]");
        assert_eq!(bits(a.raw_s[t]), bits(b.raw_s[t]), "{ctx}: raw_s[{t}]");
    }
    assert_eq!(bits(a.raw_g_sq_total), bits(b.raw_g_sq_total), "{ctx}: raw_g_sq_total");
    assert_eq!(bits(a.raw_s_total), bits(b.raw_s_total), "{ctx}: raw_s_total");
    assert_eq!(bits(a.gns_layernorm), bits(b.gns_layernorm), "{ctx}: gns_layernorm");
    assert_eq!(bits(a.gns_total), bits(b.gns_total), "{ctx}: gns_total");
}

fn run_steps(tr: &mut Trainer, n: usize) -> Vec<StepRecord> {
    (0..n).map(|_| tr.step().unwrap()).collect()
}

fn temp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("nanogns_pr8_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[cfg(unix)]
fn kill9(pid: u32) {
    let status = std::process::Command::new("kill")
        .args(["-9", &pid.to_string()])
        .status()
        .expect("spawning kill");
    assert!(status.success(), "kill -9 {pid} failed");
}

/// The tentpole property: swapping scoped threads for supervised child
/// processes changes nothing about the numbers. Rank counts 1 and 3,
/// worker counts 1 (all ranks on one child) and ranks (one child each).
#[test]
fn process_mode_is_bitwise_identical_to_thread_mode() {
    for ranks in [1usize, 3] {
        let mut thread_tr =
            Trainer::with_rank_workers(&ReferenceFactory, base_cfg(3, ranks), 1).unwrap();
        let want = run_steps(&mut thread_tr, 3);
        let worker_counts: &[usize] = if ranks == 1 { &[1] } else { &[1, ranks] };
        for &workers in worker_counts {
            let mut proc_tr =
                Trainer::with_rank_workers(&ReferenceFactory, elastic_cfg(3, ranks), workers)
                    .unwrap();
            assert_eq!(proc_tr.rank_workers(), workers);
            assert!(proc_tr.elastic_worker_pids().is_some(), "process engine expected");
            let got = run_steps(&mut proc_tr, 3);
            for (a, b) in got.iter().zip(&want) {
                let ctx = format!("ranks={ranks} workers={workers} step={}", b.step);
                assert_records_eq(a, b, &ctx);
            }
        }
    }
}

/// Process mode reports real per-rank liveness (pids, heartbeat ages);
/// thread mode synthesizes always-alive entries.
#[test]
fn rank_health_reflects_engine_mode() {
    let mut tr = Trainer::with_rank_workers(&ReferenceFactory, elastic_cfg(2, 2), 2).unwrap();
    run_steps(&mut tr, 1);
    let health = tr.rank_health();
    assert_eq!(health.len(), 2);
    for (i, h) in health.iter().enumerate() {
        assert_eq!(h.rank, i);
        assert!(h.alive);
        assert_eq!(h.mode, "process");
        assert!(h.pid.is_some());
        assert!(h.heartbeat_age_ms.is_some());
    }
    let tr2 = Trainer::with_rank_workers(&ReferenceFactory, base_cfg(2, 2), 1).unwrap();
    for h in tr2.rank_health() {
        assert_eq!(h.mode, "thread");
        assert!(h.pid.is_none());
    }
}

/// kill -9 one rank worker between steps: the next step attempt loses
/// the rank, the trainer reconciles, and the surviving ranks' records
/// are bitwise identical to a thread-mode run that dropped the same
/// rank position at the same step boundary. Respawn is disabled so the
/// run stays at the reduced rank count (the rejoin path has its own
/// test below).
#[cfg(unix)]
#[test]
fn killed_worker_reconciles_bitwise_to_reduced_thread_run() {
    let ranks = 3;
    // Control trajectory: thread mode, same drop applied by hand.
    let mut control = Trainer::with_rank_workers(&ReferenceFactory, base_cfg(6, ranks), 1).unwrap();
    let want_head = run_steps(&mut control, 2);
    control.drop_ranks(&[1]).unwrap();
    let want_tail = run_steps(&mut control, 4);

    // Elastic run: one child per rank, murder the middle one.
    let mut cfg = elastic_cfg(6, ranks);
    cfg.elastic.max_respawns = 0;
    let mut tr = Trainer::with_rank_workers(&ReferenceFactory, cfg, ranks).unwrap();
    let head = run_steps(&mut tr, 2);
    for (a, b) in head.iter().zip(&want_head) {
        assert_records_eq(a, b, &format!("pre-kill step {}", b.step));
    }
    let pids = tr.elastic_worker_pids().unwrap();
    assert_eq!(pids.len(), ranks);
    kill9(pids[1]);
    let tail = run_steps(&mut tr, 4);
    assert_eq!(tr.ranks(), ranks - 1, "dead rank must be reconciled away");
    for (a, b) in tail.iter().zip(&want_tail) {
        assert_records_eq(a, b, &format!("post-kill step {}", b.step));
    }
}

/// Kills one rank worker right after a chosen step completes, from
/// inside the observer hook — deterministic mid-run fault injection.
struct KillAt {
    step: u64,
    pid: u32,
    fired: AtomicBool,
}

impl StepObserver for KillAt {
    fn on_step(&self, obs: &StepObservation<'_>) {
        if obs.record.step == self.step && !self.fired.swap(true, Ordering::SeqCst) {
            kill9(self.pid);
        }
    }
}

/// The acceptance scenario: a full `run()` with checkpointing survives a
/// worker killed mid-run, finishes its entire step budget on the
/// survivors, and parks a loadable final checkpoint at the reduced rank
/// count. Respawn is disabled so the reduced count is the terminal state.
#[cfg(unix)]
#[test]
fn run_survives_midrun_kill_and_parks_loadable_checkpoint() {
    let dir = temp_dir("midrun_kill");
    let steps = 6u64;
    let mut cfg = elastic_cfg(steps, 3);
    cfg.elastic.max_respawns = 0;
    cfg.checkpoint_dir = dir.to_string_lossy().into_owned();
    cfg.checkpoint_every = 1;
    let mut tr = Trainer::with_rank_workers(&ReferenceFactory, cfg, 3).unwrap();
    let pids = tr.elastic_worker_pids().unwrap();
    let obs = KillAt { step: 2, pid: pids[2], fired: AtomicBool::new(false) };
    let out = tr.run_with_observer(Some(&obs)).unwrap();
    assert!(obs.fired.load(Ordering::SeqCst), "kill never fired");
    assert_eq!(out.records.len(), steps as usize, "every budgeted step must complete");
    assert_eq!(tr.ranks(), 2, "run must end on the survivors");
    assert!(out.final_loss.is_finite());

    // The final checkpoint is good: readable, at the final step, with
    // one loader cursor per *surviving* rank.
    let entry = ReferenceFactory.describe("nano").unwrap();
    let st = checkpoint::load_state(dir.join("latest.ckpt"), &entry).unwrap();
    assert_eq!(st.step, steps);
    assert_eq!(st.loaders.len(), 2);
    // No partial writes left behind.
    assert!(checkpoint::clean_stale_tmps(&dir).unwrap().is_empty());
}

/// Crash-mid-write recovery: truncated `.ckpt.tmp` files next to a good
/// checkpoint are cleaned up on the next run, and resuming loads the
/// previous good checkpoint with the uninterrupted trajectory.
#[test]
fn stale_tmps_are_cleaned_and_resume_uses_previous_good_checkpoint() {
    let dir = temp_dir("crash_resume");
    let mut cfg = base_cfg(6, 2);
    cfg.checkpoint_dir = dir.to_string_lossy().into_owned();
    cfg.checkpoint_every = 2;

    let mut full = Trainer::new(&ReferenceFactory, cfg.clone()).unwrap();
    let out = full.run().unwrap();
    assert_eq!(out.records.len(), 6);

    // Simulate dying inside the *next* checkpoint's publish: a truncated
    // image under the tmp name. The renamed-over checkpoints are intact.
    let good = std::fs::read(dir.join("step-00000004.ckpt")).unwrap();
    std::fs::write(dir.join("latest.ckpt.tmp"), &good[..good.len() / 2]).unwrap();
    std::fs::write(dir.join("step-00000099.ckpt.tmp"), b"torn write").unwrap();

    let mut resumed =
        Trainer::resume(&ReferenceFactory, cfg, dir.join("step-00000004.ckpt")).unwrap();
    assert_eq!(resumed.runner.step, 4);
    let tail = resumed.run().unwrap();
    assert_eq!(tail.records.len(), 2, "resume runs only the remaining budget");
    for (a, b) in tail.records.iter().zip(&out.records[4..]) {
        assert_records_eq(a, b, &format!("resumed step {}", b.step));
    }
    assert!(!dir.join("latest.ckpt.tmp").exists(), "stale tmp must be removed");
    assert!(!dir.join("step-00000099.ckpt.tmp").exists(), "stale tmp must be removed");
    // ... without touching published checkpoints.
    assert!(dir.join("step-00000004.ckpt").exists());
}

/// The async writer publishes byte-identical images to the synchronous
/// path, to every requested path, and double-buffers across submissions.
#[test]
fn async_checkpoints_are_byte_identical_to_sync_saves() {
    let dir = temp_dir("async_bytes");
    let mut cfg = base_cfg(5, 2);
    cfg.checkpoint_dir = dir.to_string_lossy().into_owned();
    let mut tr = Trainer::new(&ReferenceFactory, cfg).unwrap();
    run_steps(&mut tr, 2);

    let step_path = tr.checkpoint_now().unwrap();
    tr.wait_checkpoints().unwrap();
    let sync_path = dir.join("sync.ckpt");
    tr.save_checkpoint(&sync_path).unwrap();
    let sync_bytes = std::fs::read(&sync_path).unwrap();
    assert_eq!(std::fs::read(&step_path).unwrap(), sync_bytes, "step file differs");
    assert_eq!(std::fs::read(dir.join("latest.ckpt")).unwrap(), sync_bytes, "latest differs");

    // Back-to-back submissions (buffer recycling + the bounded queue).
    run_steps(&mut tr, 1);
    let p1 = tr.checkpoint_now().unwrap();
    run_steps(&mut tr, 1);
    let p2 = tr.checkpoint_now().unwrap();
    tr.wait_checkpoints().unwrap();
    assert!(p1.exists() && p2.exists());
    assert_ne!(p1, p2);
    let entry = ReferenceFactory.describe("nano").unwrap();
    assert_eq!(checkpoint::load_state(&p2, &entry).unwrap().step, 4);
}

/// The respawn/rejoin acceptance scenario: kill a worker mid-run, let
/// the supervisor respawn it, and check the whole trajectory — reduced
/// steps *and* post-rejoin full-rank steps — bitwise against a control
/// run that applies the same drop/readmit transitions at the same step
/// boundaries. The control is thread-mode `drop_ranks`/`readmit_ranks`,
/// driven by the rank counts the elastic run actually exhibited (respawn
/// timing is backoff-paced, so the boundary is observed, not assumed).
#[cfg(unix)]
#[test]
fn killed_worker_respawns_and_rejoins_bitwise() {
    let ranks = 3;
    let steps = 12u64;
    let mut cfg = elastic_cfg(steps, ranks);
    // Near-zero backoff: the respawn happens at the first step boundary
    // after the death is reconciled.
    cfg.elastic.respawn_backoff_ms = 1;
    cfg.elastic.respawn_backoff_max_ms = 1000;
    let mut tr = Trainer::with_rank_workers(&ReferenceFactory, cfg, ranks).unwrap();
    let head = run_steps(&mut tr, 2);
    let pids = tr.elastic_worker_pids().unwrap();
    kill9(pids[1]);
    // Record the rank count each remaining step actually ran at: the
    // reconciling step completes on the survivors (count drops), the
    // rejoin boundary re-admits before stepping (count recovers).
    let mut tail = Vec::new();
    let mut counts = Vec::new();
    for _ in 2..steps {
        tail.push(tr.step().unwrap());
        counts.push(tr.ranks());
    }
    assert!(counts.contains(&(ranks - 1)), "kill never dropped a rank: {counts:?}");
    assert!(
        counts.windows(2).any(|w| w[0] == ranks - 1 && w[1] == ranks),
        "worker never rejoined: {counts:?}"
    );
    assert_eq!(*counts.last().unwrap(), ranks, "run must end at full rank count");

    // Control: thread mode, replaying the observed transitions. The
    // killed worker owned exactly original rank 1 (one rank per worker).
    let mut control = Trainer::with_rank_workers(&ReferenceFactory, base_cfg(steps, ranks), 1).unwrap();
    let want_head = run_steps(&mut control, 2);
    for (a, b) in head.iter().zip(&want_head) {
        assert_records_eq(a, b, &format!("pre-kill step {}", b.step));
    }
    let mut prev = ranks;
    for (i, &c) in counts.iter().enumerate() {
        if c < prev {
            control.drop_ranks(&[1]).unwrap();
        } else if c > prev {
            control.readmit_ranks(&[1]).unwrap();
        }
        let want = control.step().unwrap();
        assert_records_eq(&tail[i], &want, &format!("post-kill step {} (ranks {c})", want.step));
        prev = c;
    }
}
