//! Failure-domain integration tests (PR 9): deterministic fault
//! injection via `NANOGNS_FAULT_PLAN`, the checkpoint integrity chain,
//! and rank respawn/rejoin under injected faults.
//!
//! Subprocess scenarios drive the real `repro` binary
//! (`CARGO_BIN_EXE_repro`) with a fault plan in the child's environment:
//! the coordinator and every rank-worker child it spawns arm the same
//! plan (the env is inherited), and `worker:W`-scoped clauses target one
//! child while leaving the coordinator untouched. In-process scenarios
//! exercise the library surface directly (chain fallback past a corrupt
//! newest checkpoint, writer degradation that must fail the run at the
//! end).
//!
//! DESIGN.md's failure-domain matrix points at these tests as the
//! proof obligations for each fault class.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

use nanogns::config::TrainConfig;
use nanogns::coordinator::{checkpoint, Trainer};
use nanogns::runtime::{BackendFactory, ReferenceFactory};

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("nanogns_pr9_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run the workspace's own `repro` with a controlled fault-plan
/// environment (never inheriting one from the test runner).
fn run_repro(args: &[&str], envs: &[(&str, &str)]) -> Output {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_repro"));
    cmd.args(args);
    cmd.env_remove("NANOGNS_FAULT_PLAN");
    for (k, v) in envs {
        cmd.env(k, v);
    }
    cmd.output().expect("running repro")
}

fn stderr_str(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn stdout_str(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[track_caller]
fn assert_exit_ok(out: &Output) {
    assert!(
        out.status.success(),
        "repro failed ({:?})\n--- stdout ---\n{}\n--- stderr ---\n{}",
        out.status,
        stdout_str(out),
        stderr_str(out),
    );
}

/// Every chaos scenario must resolve as a *typed* fault, never a panic
/// in any process (worker stderr is inherited by the coordinator, so a
/// child panic shows up here too).
#[track_caller]
fn assert_no_panic(err: &str) {
    assert!(!err.contains("panicked"), "a process panicked:\n{err}");
}

/// Load a published checkpoint, returning `(step, loader_cursors)` —
/// proving both that the file passes the integrity chain and what rank
/// count the run ended at.
fn ckpt_summary(path: &Path) -> (u64, usize) {
    let entry = ReferenceFactory.describe("nano").unwrap();
    let st = checkpoint::load_state(path, &entry).unwrap();
    (st.step, st.loaders.len())
}

/// Minimal process-mode config file. The elastic supervision knobs
/// (respawn budget, backoff pacing) intentionally have no CLI flags, so
/// chaos runs are config-driven.
fn write_elastic_cfg(
    dir: &Path,
    steps: u64,
    ckpt_dir: &Path,
    every: u64,
    elastic_extra: &str,
) -> PathBuf {
    let path = dir.join("train.json");
    let exe = env!("CARGO_BIN_EXE_repro");
    let body = format!(
        r#"{{
  "model": "nano", "steps": {steps}, "seed": 0,
  "lr": {{"max_lr": 1e-3, "min_lr": 1e-4, "warmup_steps": 1, "decay_steps": {steps}}},
  "batch_size": {{"kind": "fixed", "accum": 2}},
  "ranks": 2,
  "rank_mode": "process",
  "checkpoint_dir": {ckpt:?},
  "checkpoint_every": {every},
  "elastic": {{"heartbeat_ms": 50, "spawn_timeout_s": 20.0, "worker_exe": {exe:?}{elastic_extra}}}
}}"#,
        ckpt = ckpt_dir.to_string_lossy(),
    );
    std::fs::write(&path, body).unwrap();
    path
}

/// A malformed plan must fail the process fast (exit 2) and loudly — a
/// chaos run with a silently ignored plan would pass by testing nothing.
#[test]
fn invalid_fault_plan_fails_fast() {
    let out = run_repro(
        &["train", "--model", "nano", "--steps", "1"],
        &[("NANOGNS_FAULT_PLAN", "nosuch.site@1")],
    );
    assert_eq!(out.status.code(), Some(2), "stderr: {}", stderr_str(&out));
    assert!(
        stderr_str(&out).contains("invalid NANOGNS_FAULT_PLAN"),
        "stderr: {}",
        stderr_str(&out)
    );
}

/// Transient ENOSPC on one checkpoint publish: the writer degrades
/// (keeps the image in memory, warns loudly), recovers on the next
/// publish, and the run exits 0 with a valid final checkpoint.
#[test]
fn injected_enospc_degrades_then_recovers() {
    let dir = temp_dir("enospc");
    let ckpt = dir.join("ckpts");
    let out = run_repro(
        &[
            "train",
            "--model",
            "nano",
            "--steps",
            "4",
            "--seed",
            "0",
            "--checkpoint-dir",
            ckpt.to_str().unwrap(),
            "--checkpoint-every",
            "1",
        ],
        &[("NANOGNS_FAULT_PLAN", "ckpt.enospc@3")],
    );
    assert_exit_ok(&out);
    let err = stderr_str(&out);
    assert_no_panic(&err);
    assert!(err.contains("faultkit: armed"), "plan never armed:\n{err}");
    assert!(err.contains("keeping the image in memory"), "never degraded:\n{err}");
    assert!(err.contains("publish recovered"), "never recovered:\n{err}");
    assert_eq!(ckpt_summary(&ckpt.join("latest.ckpt")), (4, 1));
}

/// A torn (truncated) write to the final `latest.ckpt` is invisible at
/// write time by design — the load-time integrity chain must catch it:
/// `--resume latest.ckpt` skips the torn file, falls back to the newest
/// step checkpoint that validates, and the run continues to completion.
#[test]
fn torn_latest_checkpoint_resume_falls_back() {
    let dir = temp_dir("torn_resume");
    let ckpt = dir.join("ckpts");
    let ckpt_s = ckpt.to_str().unwrap().to_string();
    // Publishes, in order: step-2, latest, step-4, latest — the 4th is
    // the final `latest.ckpt`, torn in half.
    let out = run_repro(
        &[
            "train", "--model", "nano", "--steps", "4", "--seed", "0", "--checkpoint-dir",
            &ckpt_s, "--checkpoint-every", "2",
        ],
        &[("NANOGNS_FAULT_PLAN", "ckpt.torn@4")],
    );
    assert_exit_ok(&out);
    assert!(stderr_str(&out).contains("torn checkpoint write"), "{}", stderr_str(&out));
    let torn = std::fs::metadata(ckpt.join("latest.ckpt")).unwrap().len();
    let good = std::fs::metadata(ckpt.join("step-00000004.ckpt")).unwrap().len();
    assert!(torn < good, "latest.ckpt should be truncated ({torn} vs {good} bytes)");

    let latest = ckpt.join("latest.ckpt");
    let resumed = run_repro(
        &[
            "train", "--model", "nano", "--steps", "6", "--seed", "0", "--checkpoint-dir",
            &ckpt_s, "--checkpoint-every", "2", "--resume", latest.to_str().unwrap(),
        ],
        &[],
    );
    assert_exit_ok(&resumed);
    let err = stderr_str(&resumed);
    assert_no_panic(&err);
    assert!(err.contains("skipping"), "torn file not reported:\n{err}");
    assert!(err.contains("fell back to"), "no chain fallback:\n{err}");
    assert!(stdout_str(&resumed).contains("at step 4"), "{}", stdout_str(&resumed));
    assert_eq!(ckpt_summary(&ckpt.join("latest.ckpt")), (6, 1));
}

/// Rank respawn under a crash-looping worker: worker 1 exits on its 2nd
/// step command in *every* incarnation, and the supervisor keeps
/// respawning and re-admitting it. The run still completes its full
/// step budget with a valid final checkpoint and exit 0.
#[test]
fn injected_worker_exit_respawns_and_completes() {
    let dir = temp_dir("worker_exit");
    let ckpt = dir.join("ckpts");
    let cfg = write_elastic_cfg(
        &dir,
        6,
        &ckpt,
        3,
        r#", "respawn_backoff_ms": 1, "respawn_backoff_max_ms": 50"#,
    );
    let out = run_repro(
        &["train", "--config", cfg.to_str().unwrap()],
        &[
            ("NANOGNS_FAULT_PLAN", "worker.exit@step:2,worker:1"),
            ("NANOGNS_RANK_WORKERS", "2"),
        ],
    );
    assert_exit_ok(&out);
    let err = stderr_str(&out);
    assert_no_panic(&err);
    assert!(err.contains("down:"), "worker death never detected:\n{err}");
    assert!(err.contains("respawned worker"), "worker never respawned:\n{err}");
    assert!(err.contains("re-admitting"), "worker never re-admitted:\n{err}");
    let (step, _live) = ckpt_summary(&ckpt.join("latest.ckpt"));
    assert_eq!(step, 6, "the full step budget must complete");
}

/// A corrupted frame is a *rank fault*, never a panic: the CRC trailer
/// catches the flipped byte, the coordinator retires the sender, and
/// the run completes on the survivor.
#[test]
fn injected_frame_corruption_is_a_rank_fault_not_a_panic() {
    let dir = temp_dir("frame_corrupt");
    let ckpt = dir.join("ckpts");
    let cfg = write_elastic_cfg(&dir, 5, &ckpt, 5, r#", "max_respawns": 0"#);
    let out = run_repro(
        &["train", "--config", cfg.to_str().unwrap()],
        &[
            ("NANOGNS_FAULT_PLAN", "frame.corrupt@4,worker:1"),
            ("NANOGNS_RANK_WORKERS", "2"),
        ],
    );
    assert_exit_ok(&out);
    let err = stderr_str(&out);
    assert_no_panic(&err);
    assert!(err.contains("corrupting outgoing frame"), "fault never fired:\n{err}");
    assert!(err.contains("crc mismatch"), "corruption not CRC-detected:\n{err}");
    assert!(err.contains("down: connection lost"), "sender not retired:\n{err}");
    assert_eq!(ckpt_summary(&ckpt.join("latest.ckpt")), (5, 1));
}

/// Transient connect failures during worker startup are absorbed by the
/// bounded retry-with-backoff — no rank is lost, nothing respawns, and
/// the run ends at full rank count.
#[test]
fn injected_connect_failures_are_retried_without_rank_loss() {
    let dir = temp_dir("connect_fail");
    let ckpt = dir.join("ckpts");
    let cfg = write_elastic_cfg(&dir, 3, &ckpt, 3, "");
    let out = run_repro(
        &["train", "--config", cfg.to_str().unwrap()],
        &[
            ("NANOGNS_FAULT_PLAN", "connect.fail@2,worker:1"),
            ("NANOGNS_RANK_WORKERS", "2"),
        ],
    );
    assert_exit_ok(&out);
    let err = stderr_str(&out);
    assert_no_panic(&err);
    assert!(err.contains("injected connect failure"), "fault never fired:\n{err}");
    assert!(!err.contains("down:"), "retried connects must not cost the rank:\n{err}");
    assert!(!err.contains("respawned worker"), "no respawn expected:\n{err}");
    assert_eq!(ckpt_summary(&ckpt.join("latest.ckpt")), (3, 2));
}

/// A worker stalled past the step deadline (a hang, not a crash) is
/// detected by the deadline, dropped, and the run completes on the
/// survivor.
#[test]
fn injected_stall_past_step_deadline_drops_the_rank() {
    let dir = temp_dir("step_stall");
    let ckpt = dir.join("ckpts");
    let cfg = write_elastic_cfg(&dir, 4, &ckpt, 4, r#", "max_respawns": 0, "step_timeout_s": 0.5"#);
    let out = run_repro(
        &["train", "--config", cfg.to_str().unwrap()],
        &[
            ("NANOGNS_FAULT_PLAN", "step.stall@2,ms:3000,worker:1"),
            ("NANOGNS_RANK_WORKERS", "2"),
        ],
    );
    assert_exit_ok(&out);
    let err = stderr_str(&out);
    assert_no_panic(&err);
    assert!(err.contains("deadline exceeded"), "stall not detected:\n{err}");
    assert!(err.contains("down:"), "stalled rank not dropped:\n{err}");
    assert_eq!(ckpt_summary(&ckpt.join("latest.ckpt")), (4, 1));
}

/// The acceptance scenario for the integrity chain, in-process: with
/// `keep_last = 3` retention, corrupt the *newest* step checkpoint and
/// resume from it. The chain skips it, loads the previous good one, and
/// the re-run trajectory is bitwise identical to the uncrashed run.
#[test]
fn resume_falls_back_past_corrupt_newest_checkpoint() {
    let dir = temp_dir("chain_fallback");
    let mut cfg = TrainConfig::quickstart("nano", 8);
    cfg.ranks = 2;
    cfg.checkpoint_dir = dir.to_string_lossy().into_owned();
    cfg.checkpoint_every = 2;
    cfg.checkpoint_keep_last = 3;
    let mut full = Trainer::new(&ReferenceFactory, cfg.clone()).unwrap();
    let want = full.run().unwrap();
    assert_eq!(want.records.len(), 8);

    // keep_last = 3 pruned step-2; 4/6/8 survive.
    assert!(!dir.join("step-00000002.ckpt").exists(), "retention never pruned");
    for s in ["step-00000004.ckpt", "step-00000006.ckpt", "step-00000008.ckpt"] {
        assert!(dir.join(s).exists(), "{s} missing");
    }

    // Corrupt the newest step checkpoint (flip a payload byte; the
    // per-section CRC must reject it).
    let newest = dir.join("step-00000008.ckpt");
    let mut bytes = std::fs::read(&newest).unwrap();
    let at = bytes.len() * 3 / 4;
    bytes[at] ^= 0x01;
    std::fs::write(&newest, &bytes).unwrap();

    let mut resumed = Trainer::resume(&ReferenceFactory, cfg, &newest).unwrap();
    assert_eq!(resumed.runner.step, 6, "must fall back to step-6, not load corrupt step-8");
    let tail = resumed.run().unwrap();
    assert_eq!(tail.records.len(), 2);
    for (a, b) in tail.records.iter().zip(&want.records[6..]) {
        assert_eq!(a.step, b.step);
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "step {}: resumed loss {} vs original {}",
            a.step,
            a.loss,
            b.loss
        );
        assert_eq!(a.gns_total.to_bits(), b.gns_total.to_bits(), "step {}: gns", a.step);
    }
}

/// A checkpoint failure that never recovers must not be silent: the
/// writer degrades during the run (training continues), and the
/// end-of-run barrier turns the sticky condition into a hard error —
/// which `repro train` exits nonzero on.
#[test]
fn persistent_checkpoint_failure_fails_the_run_loudly() {
    let dir = temp_dir("persistent_ckpt_fail");
    let ckpt = dir.join("ckpts");
    std::fs::create_dir_all(&ckpt).unwrap();
    let mut cfg = TrainConfig::quickstart("nano", 4);
    cfg.checkpoint_dir = ckpt.to_string_lossy().into_owned();
    let mut tr = Trainer::new(&ReferenceFactory, cfg).unwrap();
    tr.step().unwrap();
    // Every publish from here on fails: the checkpoint "dir" is a file.
    std::fs::remove_dir_all(&ckpt).unwrap();
    std::fs::write(&ckpt, b"not a directory").unwrap();
    // Submission itself must not error (training goes on)...
    tr.checkpoint_now().unwrap();
    // ... but the end-of-run barrier must refuse to call this run clean.
    let err = tr.wait_checkpoints().unwrap_err();
    assert!(
        format!("{err:#}").contains("checkpoint writes degraded"),
        "got: {err:#}"
    );
}
