//! Persistent worker-pool lifecycle contract (the tentpole's steady-state
//! guarantee): threads are spawned once per `ReferenceBackend`, at
//! construction — repeated training steps never create another.
//!
//! The spawn counter is process-global, so every test here runs under one
//! mutex: a concurrently constructed pool in another test of this binary
//! would otherwise move the counter mid-assertion. (Other test binaries
//! are separate processes and cannot interfere.)

use std::sync::Mutex;

use nanogns::data::{CorpusGenerator, Loader};
use nanogns::norms::{NormKind, NormPlacement};
use nanogns::runtime::kernels::{total_threads_spawned, WorkerPool};
use nanogns::runtime::{Backend, RefModelConfig, ReferenceBackend};

static COUNTER_LOCK: Mutex<()> = Mutex::new(());

fn tiny_cfg() -> RefModelConfig {
    RefModelConfig {
        d_model: 8,
        n_layers: 1,
        n_heads: 2,
        seq_len: 6,
        vocab: 11,
        microbatch: 2,
        norm: NormKind::LayerNorm,
        placement: NormPlacement::PreLn,
    }
}

#[test]
fn spawn_counter_stays_flat_across_100_steps() {
    let _g = COUNTER_LOCK.lock().unwrap();
    let be = ReferenceBackend::with_threads(tiny_cfg(), 4).unwrap();
    let params = be.init(0).unwrap();
    let text = CorpusGenerator::new(0).generate(1 << 12);
    let mut loader = Loader::new(&text, 6, 0);

    // Warmup: first step builds the workspace and exercises every kernel.
    let batch = loader.next_batch(2);
    be.grad_step(&params, &batch).unwrap();

    let spawned = total_threads_spawned();
    for _ in 0..100 {
        let batch = loader.next_batch(2);
        let out = be.grad_step(&params, &batch).unwrap();
        assert!(out.loss.is_finite());
    }
    assert_eq!(
        total_threads_spawned(),
        spawned,
        "steady-state grad steps must not spawn threads"
    );
}

#[test]
fn pool_construction_is_the_only_spawn_site() {
    let _g = COUNTER_LOCK.lock().unwrap();
    let before = total_threads_spawned();
    let pool = WorkerPool::new(3);
    let after_build = total_threads_spawned();
    assert_eq!(after_build - before, 2, "N workers = N-1 spawned threads + the caller");

    let n_tasks = 64usize;
    for _ in 0..50 {
        let hits = std::sync::atomic::AtomicU64::new(0);
        pool.run(n_tasks, &|ti| {
            hits.fetch_add(1 + ti as u64, std::sync::atomic::Ordering::Relaxed);
        });
        // every task index ran exactly once: Σ (1 + ti)
        let want = n_tasks as u64 + (n_tasks as u64 * (n_tasks as u64 - 1)) / 2;
        assert_eq!(hits.load(std::sync::atomic::Ordering::Relaxed), want);
    }
    // 50 dispatches later: still only the construction-time spawns.
    assert_eq!(total_threads_spawned(), after_build, "run() must never spawn");
}

/// A second backend gets its own pool (counter moves at construction,
/// by exactly workers-1), and dropping it joins the threads without
/// disturbing the counter.
#[test]
fn each_backend_owns_one_pool() {
    let _g = COUNTER_LOCK.lock().unwrap();
    let before = total_threads_spawned();
    let be = ReferenceBackend::with_threads(tiny_cfg(), 3).unwrap();
    assert_eq!(total_threads_spawned() - before, 2);
    drop(be);
    assert_eq!(total_threads_spawned() - before, 2, "drop joins, never spawns");
    let single = ReferenceBackend::with_threads(tiny_cfg(), 1).unwrap();
    assert_eq!(total_threads_spawned() - before, 2, "1-worker pool spawns nothing");
    drop(single);
}
