//! Runtime integration tests against the real nano artifacts.
//!
//! Require `make artifacts` to have run (skipped with a message otherwise,
//! so pure-Rust unit tests never depend on Python).

use nanogns::coordinator::ModelRunner;
use nanogns::data::{CorpusGenerator, Loader};
use nanogns::runtime::{tensor, Manifest, Runtime};

fn setup() -> Option<(Runtime, Manifest)> {
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping runtime integration tests: {e}");
            return None;
        }
    };
    Some((Runtime::cpu().expect("pjrt cpu"), manifest))
}

#[test]
fn manifest_and_artifacts_load() {
    let Some((rt, manifest)) = setup() else { return };
    let exes = rt.load_model(&manifest, "nano").unwrap();
    assert!(exes.len() >= 6);
    // cached: a second load returns the same Rc
    let entry = manifest.config("nano").unwrap();
    let p = entry.artifact_path(&manifest.root, "init").unwrap();
    let a = rt.load(&p).unwrap();
    let b = rt.load(&p).unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b));
}

#[test]
fn init_produces_manifest_shapes() {
    let Some((rt, manifest)) = setup() else { return };
    let mut runner = ModelRunner::new(&rt, &manifest, "nano").unwrap();
    runner.init(0).unwrap();
    let entry = manifest.config("nano").unwrap();
    for (spec, lit) in entry.params.iter().zip(&runner.params) {
        let t = tensor::Tensor::from_literal(lit).unwrap();
        assert_eq!(t.shape, spec.shape, "{}", spec.name);
    }
    // gamma initialized to ones
    let i = entry.params.iter().position(|p| p.name == "h0.ln1.g").unwrap();
    let g = tensor::Tensor::from_literal(&runner.params[i]).unwrap();
    assert!(g.data.iter().all(|&v| v == 1.0));
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let Some((rt, manifest)) = setup() else { return };
    let mut a = ModelRunner::new(&rt, &manifest, "nano").unwrap();
    let mut b = ModelRunner::new(&rt, &manifest, "nano").unwrap();
    a.init(3).unwrap();
    b.init(3).unwrap();
    let ta = tensor::Tensor::from_literal(&a.params[0]).unwrap();
    let tb = tensor::Tensor::from_literal(&b.params[0]).unwrap();
    assert_eq!(ta, tb);
    b.init(4).unwrap();
    let tb2 = tensor::Tensor::from_literal(&b.params[0]).unwrap();
    assert_ne!(ta, tb2);
}

#[test]
fn grad_step_outputs_are_sane() {
    let Some((rt, manifest)) = setup() else { return };
    let mut runner = ModelRunner::new(&rt, &manifest, "nano").unwrap();
    runner.init(1).unwrap();
    let text = CorpusGenerator::new(1).generate(1 << 16);
    let mut loader = Loader::new(&text, runner.entry.seq_len, 1);
    let out = runner.grad_microbatch(&loader.next_batch(runner.entry.microbatch)).unwrap();
    // random-init loss ~ ln(256)
    assert!((out.loss - (256f32).ln()).abs() < 1.0, "loss {}", out.loss);
    assert_eq!(out.grads.len(), runner.n_params_tensors());
    // stats strictly positive for every layer type
    for (t, s) in nanogns::STATS_ORDER.iter().zip(out.stats) {
        assert!(s > 0.0, "stats[{t}] = {s}");
    }
}

#[test]
fn grad_sqnorms_matches_host_computation() {
    let Some((rt, manifest)) = setup() else { return };
    let mut runner = ModelRunner::new(&rt, &manifest, "nano").unwrap();
    runner.init(2).unwrap();
    let text = CorpusGenerator::new(2).generate(1 << 16);
    let mut loader = Loader::new(&text, runner.entry.seq_len, 2);
    let out = runner.grad_microbatch(&loader.next_batch(runner.entry.microbatch)).unwrap();
    let device = runner.grad_sqnorms(&out.grads).unwrap();
    // recompute on host
    let entry = manifest.config("nano").unwrap();
    let mut host = [0f64; nanogns::N_TYPES];
    for (spec, g) in entry.params.iter().zip(&out.grads) {
        let t = tensor::Tensor::from_literal(g).unwrap();
        let idx = nanogns::STATS_ORDER.iter().position(|s| *s == spec.ltype).unwrap();
        host[idx] += t.sq_norm();
    }
    for (d, h) in device.iter().zip(host) {
        assert!((d - h).abs() <= 1e-4 * h.max(1e-12), "{d} vs {h}");
    }
}

#[test]
fn accumulation_equals_sum() {
    let Some((rt, manifest)) = setup() else { return };
    let mut runner = ModelRunner::new(&rt, &manifest, "nano").unwrap();
    runner.init(3).unwrap();
    let text = CorpusGenerator::new(3).generate(1 << 16);
    let mut loader = Loader::new(&text, runner.entry.seq_len, 3);
    let b1 = loader.next_batch(runner.entry.microbatch);
    let b2 = loader.next_batch(runner.entry.microbatch);
    let g1 = runner.grad_microbatch(&b1).unwrap().grads;
    let g2 = runner.grad_microbatch(&b2).unwrap().grads;
    let acc = runner.accumulate(runner.zero_grads().unwrap(), &g1).unwrap();
    let acc = runner.accumulate(acc, &g2).unwrap();
    for ((a, x), y) in acc.iter().zip(&g1).zip(&g2) {
        let ta = tensor::Tensor::from_literal(a).unwrap();
        let tx = tensor::Tensor::from_literal(x).unwrap();
        let ty = tensor::Tensor::from_literal(y).unwrap();
        for i in 0..ta.data.len() {
            let want = tx.data[i] + ty.data[i];
            assert!((ta.data[i] - want).abs() <= 1e-5 * want.abs().max(1e-3));
        }
    }
}

#[test]
fn adam_update_decreases_loss_on_same_batch() {
    let Some((rt, manifest)) = setup() else { return };
    let mut runner = ModelRunner::new(&rt, &manifest, "nano").unwrap();
    runner.init(4).unwrap();
    let text = CorpusGenerator::new(4).generate(1 << 16);
    let mut loader = Loader::new(&text, runner.entry.seq_len, 4);
    let batch = loader.next_batch(runner.entry.microbatch);
    let before = runner.eval(&batch).unwrap();
    for _ in 0..3 {
        let out = runner.grad_microbatch(&batch).unwrap();
        runner.adamw_update(&out.grads, 1e-3, 1.0).unwrap();
    }
    let after = runner.eval(&batch).unwrap();
    assert!(after < before, "{after} !< {before}");
}

#[test]
fn checkpoint_round_trip() {
    let Some((rt, manifest)) = setup() else { return };
    let mut runner = ModelRunner::new(&rt, &manifest, "nano").unwrap();
    runner.init(5).unwrap();
    let entry = manifest.config("nano").unwrap();
    let dir = std::env::temp_dir().join("nanogns_ckpt_test");
    let path = dir.join("nano.ckpt");
    nanogns::coordinator::checkpoint::save(&path, entry, &runner.params).unwrap();
    let loaded = nanogns::coordinator::checkpoint::load(&path, entry).unwrap();
    for (a, b) in runner.params.iter().zip(&loaded) {
        assert_eq!(
            tensor::Tensor::from_literal(a).unwrap(),
            tensor::Tensor::from_literal(b).unwrap()
        );
    }
}
