//! Runner-level integration tests against the reference backend.
//!
//! Hermetic: these run on a bare machine with no Python, no HLO artifacts
//! and no xla_extension — the pure-Rust backend executes everything. The
//! same assertions hold for the pjrt backend when its artifacts exist.

use nanogns::coordinator::ModelRunner;
use nanogns::data::{CorpusGenerator, Loader};
use nanogns::runtime::{Backend, BackendFactory, Buffer, ReferenceBackend, ReferenceFactory};

fn runner(seed: i32) -> ModelRunner {
    let mut r = ModelRunner::new(&ReferenceFactory, "nano").expect("create nano backend");
    r.init(seed).expect("init");
    r
}

fn loader_for(runner: &ModelRunner, seed: u64) -> Loader {
    let text = CorpusGenerator::new(seed).generate(1 << 16);
    Loader::new(&text, runner.entry.seq_len, seed)
}

#[test]
fn factory_lists_and_describes_every_preset() {
    let f = ReferenceFactory;
    let models = f.models();
    assert!(models.iter().any(|m| m == "nano"), "{models:?}");
    for m in &models {
        let entry = f.describe(m).unwrap();
        let built = f.create(m).unwrap();
        assert_eq!(entry.n_params, built.entry().n_params, "{m}");
        assert_eq!(entry.params.len(), built.entry().params.len(), "{m}");
    }
    assert!(f.create("no-such-model").is_err());
}

#[test]
fn init_produces_entry_shapes() {
    let runner = runner(0);
    for (spec, buf) in runner.entry.params.iter().zip(&runner.params) {
        let t = buf.to_tensor().unwrap();
        assert_eq!(t.shape, spec.shape, "{}", spec.name);
    }
    // gamma initialized to ones
    let i = runner.entry.params.iter().position(|p| p.name == "h0.ln1.g").unwrap();
    let g = runner.params[i].to_tensor().unwrap();
    assert!(g.data.iter().all(|&v| v == 1.0));
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let a = runner(3);
    let b = runner(3);
    let ta = a.params[0].to_tensor().unwrap();
    let tb = b.params[0].to_tensor().unwrap();
    assert_eq!(ta, tb);
    let c = runner(4);
    let tc = c.params[0].to_tensor().unwrap();
    assert_ne!(ta, tc);
}

#[test]
fn grad_step_outputs_are_sane() {
    let runner = runner(1);
    let mut loader = loader_for(&runner, 1);
    let out = runner.grad_microbatch(&loader.next_batch(runner.entry.microbatch)).unwrap();
    // random-init loss ~ ln(256)
    assert!((out.loss - (256f32).ln()).abs() < 1.0, "loss {}", out.loss);
    assert_eq!(out.grads.len(), runner.n_params_tensors());
    // stats strictly positive for every layer type
    for (t, s) in nanogns::STATS_ORDER.iter().zip(out.stats) {
        assert!(s > 0.0, "stats[{t}] = {s}");
    }
}

#[test]
fn grad_sqnorms_matches_host_computation() {
    let runner = runner(2);
    let mut loader = loader_for(&runner, 2);
    let out = runner.grad_microbatch(&loader.next_batch(runner.entry.microbatch)).unwrap();
    let device = runner.grad_sqnorms(&out.grads).unwrap();
    // recompute on host
    let mut host = [0f64; nanogns::N_TYPES];
    for (spec, g) in runner.entry.params.iter().zip(&out.grads) {
        let t = g.to_tensor().unwrap();
        let idx = nanogns::STATS_ORDER.iter().position(|s| *s == spec.ltype).unwrap();
        host[idx] += t.sq_norm();
    }
    for (d, h) in device.iter().zip(host) {
        assert!((d - h).abs() <= 1e-4 * h.max(1e-12), "{d} vs {h}");
    }
}

#[test]
fn accumulation_equals_sum() {
    let runner = runner(3);
    let mut loader = loader_for(&runner, 3);
    let b1 = loader.next_batch(runner.entry.microbatch);
    let b2 = loader.next_batch(runner.entry.microbatch);
    let g1 = runner.grad_microbatch(&b1).unwrap().grads;
    let g2 = runner.grad_microbatch(&b2).unwrap().grads;
    let acc = runner.accumulate(runner.zero_grads().unwrap(), &g1).unwrap();
    let acc = runner.accumulate(acc, &g2).unwrap();
    for ((a, x), y) in acc.iter().zip(&g1).zip(&g2) {
        let ta = a.to_tensor().unwrap();
        let tx = x.to_tensor().unwrap();
        let ty = y.to_tensor().unwrap();
        for i in 0..ta.data.len() {
            let want = tx.data[i] + ty.data[i];
            assert!((ta.data[i] - want).abs() <= 1e-5 * want.abs().max(1e-3));
        }
    }
}

#[test]
fn adam_update_decreases_loss_on_same_batch() {
    let mut runner = runner(4);
    let mut loader = loader_for(&runner, 4);
    let batch = loader.next_batch(runner.entry.microbatch);
    let before = runner.eval(&batch).unwrap();
    for _ in 0..5 {
        let out = runner.grad_microbatch(&batch).unwrap();
        runner.adamw_update(&out.grads, 3e-3, 1.0).unwrap();
    }
    let after = runner.eval(&batch).unwrap();
    assert!(after < before, "{after} !< {before}");
}

#[test]
fn batch_shape_mismatch_is_rejected() {
    let runner = runner(6);
    let mut loader = loader_for(&runner, 6);
    let bad = loader.next_batch(runner.entry.microbatch + 1);
    assert!(runner.grad_microbatch(&bad).is_err());
    assert!(runner.eval(&bad).is_err());
}

/// The fused batched grad_step against the retained per-example oracle on
/// real loader data at preset scale (unit tests cover random tiny shapes).
#[test]
fn fused_grad_step_matches_per_example_oracle_on_nano() {
    let runner = runner(7);
    let mut loader = loader_for(&runner, 7);
    let batch = loader.next_batch(runner.entry.microbatch);
    let fused = runner.grad_microbatch(&batch).unwrap();
    let oracle = ReferenceBackend::from_preset("nano").unwrap();
    let want = oracle.grad_step_per_example(&runner.params, &batch).unwrap();
    assert!((fused.loss - want.loss).abs() <= 1e-5 * want.loss.abs().max(1e-6));
    for (t, (a, b)) in nanogns::STATS_ORDER.iter().zip(fused.stats.iter().zip(want.stats)) {
        assert!(
            (*a as f64 - b as f64).abs() <= 1e-4 * (b as f64).abs().max(1e-10),
            "stats[{t}]: fused {a} vs oracle {b}"
        );
    }
    for (spec, (g, w)) in runner.entry.params.iter().zip(fused.grads.iter().zip(&want.grads)) {
        let gt = g.to_tensor().unwrap();
        let wt = w.to_tensor().unwrap();
        let scale = wt.data.iter().fold(0f32, |m, v| m.max(v.abs()));
        for (x, y) in gt.data.iter().zip(&wt.data) {
            assert!(
                (x - y).abs() <= 1e-5 * y.abs() + 1e-5 * scale + 1e-12,
                "{}: {x} vs {y}",
                spec.name
            );
        }
    }
}

/// Gradient arena (satellite): leased sets are zeroed regardless of what
/// was recycled, and behave exactly like fresh `zero_grads` buffers.
#[test]
fn grad_arena_lease_recycle_round_trip() {
    let runner = runner(8);
    let mut loader = loader_for(&runner, 8);
    let batch = loader.next_batch(runner.entry.microbatch);
    let out = runner.grad_microbatch(&batch).unwrap();

    // Dirty a leased set, recycle it, lease again: must come back zeroed.
    let mut dirty = runner.lease_zero_grads().unwrap();
    for b in dirty.iter_mut() {
        let mut t = b.to_tensor().unwrap();
        t.data.fill(42.0);
        *b = Buffer::Host(t);
    }
    runner.recycle_grads(dirty);
    let leased = runner.lease_zero_grads().unwrap();
    assert_eq!(leased.len(), runner.n_params_tensors());
    for b in &leased {
        assert!(b.to_tensor().unwrap().data.iter().all(|&v| v == 0.0));
    }

    // Accumulating into a leased set equals accumulating into fresh zeros.
    let fresh = runner.accumulate(runner.zero_grads().unwrap(), &out.grads).unwrap();
    let reused = runner.accumulate(leased, &out.grads).unwrap();
    for (a, b) in fresh.iter().zip(&reused) {
        assert_eq!(a.to_tensor().unwrap(), b.to_tensor().unwrap());
    }

    // Recycling junk (wrong arity) is a no-op, not a panic.
    runner.recycle_grads(Vec::new());
}

#[test]
fn checkpoint_round_trip() {
    let runner = runner(5);
    let entry = &runner.entry;
    let dir = std::env::temp_dir().join("nanogns_ckpt_test");
    let path = dir.join("nano.ckpt");
    nanogns::coordinator::checkpoint::save(&path, entry, &runner.params).unwrap();
    let loaded = nanogns::coordinator::checkpoint::load(&path, entry).unwrap();
    for (a, b) in runner.params.iter().zip(&loaded) {
        assert_eq!(a.to_tensor().unwrap(), b.to_tensor().unwrap());
    }
}
