//! Minimal offline stand-in for the `anyhow` crate.
//!
//! This workspace builds with no network access, so the real `anyhow`
//! cannot be fetched from crates.io. This shim implements the subset of
//! the API the workspace uses — `Error`, `Result`, `Context`, and the
//! `anyhow!` / `bail!` / `ensure!` macros — with the same semantics:
//! a dynamic error type that any `std::error::Error` converts into, plus
//! layered human-readable context.
//!
//! Notable (intentional) divergence: `Display` prints the whole context
//! chain (`outer: inner: root`) rather than only the outermost message,
//! which makes single-line `{e}` logging self-contained.

use std::fmt;

/// Dynamic error: a root message plus layered context strings.
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), context: Vec::new() }
    }

    fn push_context(mut self, context: String) -> Self {
        self.context.push(context);
        self
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for c in self.context.iter().rev() {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent (no overlap with the reflexive `From<Error> for Error`).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach human-readable context to an error while propagating it.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.push_context(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.push_context(f().to_string()))
    }
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).push_context(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).push_context(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(s)
    }

    #[test]
    fn io_error_converts_and_takes_context() {
        let e = fails_io().context("reading config").unwrap_err();
        let s = format!("{e}");
        assert!(s.starts_with("reading config: "), "{s}");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
        fn inner(v: usize) -> Result<()> {
            ensure!(v < 2, "v too big: {v}");
            if v == 1 {
                bail!("one is not allowed");
            }
            Ok(())
        }
        assert!(inner(0).is_ok());
        assert_eq!(format!("{}", inner(1).unwrap_err()), "one is not allowed");
        assert_eq!(format!("{}", inner(5).unwrap_err()), "v too big: 5");
        fn bare(v: usize) -> Result<()> {
            ensure!(v == 0);
            Ok(())
        }
        assert!(format!("{}", bare(1).unwrap_err()).contains("condition failed"));
    }

    #[test]
    fn context_layers_print_outermost_first() {
        let e = Error::msg("root").push_context("mid".into()).push_context("outer".into());
        assert_eq!(format!("{e}"), "outer: mid: root");
        assert_eq!(e.root_cause(), "root");
    }
}
