//! Compile-only stub of the `xla` (PJRT) crate surface used by nanogns.
//!
//! The real crate wraps `xla_extension` (a native XLA build) and cannot be
//! fetched or linked in this offline workspace. This stub keeps the
//! `pjrt` feature *compiling* so the PJRT execution path stays type-checked;
//! every operation that would need the native runtime returns an error at
//! run time. To actually execute HLO artifacts, patch the workspace to the
//! real crate (see DESIGN.md §6).
//!
//! `Literal` is implemented functionally (it is plain host data), so
//! host-side conversions and round-trips work even under the stub.

use std::borrow::Borrow;
use std::path::Path;

/// Stub error type; mirrors the `Debug`-printable error of the real crate.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: xla/PJRT stub — build against the real `xla` crate (xla_extension) to \
         execute artifacts"
    ))
}

/// Element types the workspace uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Array shape of a literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Rust scalar types that map onto XLA element types.
pub trait NativeType: Copy {
    const TY: ElementType;
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(d: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<&[f32]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<&[i32]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Host tensor value, functionally implemented.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Self {
        Literal { dims: Vec::new(), data: T::wrap(vec![v]) }
    }

    pub fn vec1<T: NativeType>(v: &[T]) -> Self {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    fn numel(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.numel() {
            return Err(Error(format!("reshape {:?}: element count != {}", dims, self.numel())));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(match &self.data {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error(format!("to_vec: literal is {:?}, not {:?}", self.ty(), T::TY)))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::unwrap(&self.data)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| Error("get_first_element: empty or wrong-typed literal".into()))
    }

    /// Untuple — the stub never produces tuple literals.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// HLO module handle. Parsing requires the native text parser.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        Err(unavailable(&format!("HloModuleProto::from_text_file({:?})", path.as_ref())))
    }
}

/// Computation handle built from a proto.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Loaded executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client. Construction fails in the stub: there is no runtime.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(l.ty().unwrap(), ElementType::F32);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn client_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
    }
}
