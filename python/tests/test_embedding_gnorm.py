"""Embedding per-example gradient norms: pairwise identity vs one-hot oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import embedding, ref


def _case(seed, b, t, d, v):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    ids = jax.random.randint(ks[0], (b, t), 0, v)
    g = jax.random.normal(ks[1], (b, t, d), dtype=jnp.float32)
    return ids, g


@pytest.mark.parametrize("b,t,d,v", [(2, 4, 8, 16), (3, 8, 4, 5), (1, 6, 16, 50)])
def test_pairwise_matches_onehot(b, t, d, v):
    ids, g = _case(0, b, t, d, v)
    n0 = embedding.embedding_perex_sqnorm(ids, g)
    _, n1 = ref.embedding_perex_sqnorm_onehot(ids, g, v)
    np.testing.assert_allclose(n0, n1, rtol=1e-4, atol=1e-5)


def test_grad_matches_onehot():
    ids, g = _case(1, 2, 8, 4, 10)
    w0 = embedding.embedding_grad(ids, g, 10)
    w1, _ = ref.embedding_perex_sqnorm_onehot(ids, g, 10)
    np.testing.assert_allclose(w0, w1, rtol=1e-5, atol=1e-6)


def test_matches_vmap_gold_standard():
    """Pairwise norms == per-example grads of an actual gather, via vmap."""
    v, d = 12, 8
    ids, g = _case(2, 3, 6, d, v)
    table = jax.random.normal(jax.random.PRNGKey(9), (v, d))

    def per_example(idb, gb):
        def f(tbl):
            return jnp.sum(tbl[idb] * gb)

        return jax.grad(f)(table)

    wb = jax.vmap(per_example)(ids, g)
    nr = jax.vmap(lambda w: jnp.sum(w * w))(wb)
    n0 = embedding.embedding_perex_sqnorm(ids, g)
    np.testing.assert_allclose(n0, nr, rtol=1e-4, atol=1e-5)


def test_repeated_tokens_interfere():
    """Repeats must add coherently: with all tokens equal, n^2 = ||sum g||^2."""
    b, t, d = 2, 5, 4
    g = jax.random.normal(jax.random.PRNGKey(3), (b, t, d))
    ids = jnp.zeros((b, t), dtype=jnp.int32)
    n = embedding.embedding_perex_sqnorm(ids, g)
    expect = jnp.sum(jnp.square(g.sum(axis=1)), axis=-1)
    np.testing.assert_allclose(n, expect, rtol=1e-5)


def test_distinct_tokens_sum_rows():
    """All-distinct tokens: n^2 = sum_t ||g_t||^2 (no cross terms)."""
    b, t, d = 1, 4, 8
    g = jax.random.normal(jax.random.PRNGKey(4), (b, t, d))
    ids = jnp.arange(t, dtype=jnp.int32)[None]
    n = embedding.embedding_perex_sqnorm(ids, g)
    np.testing.assert_allclose(n, jnp.sum(g * g), rtol=1e-5)


def test_position_norm():
    g = jax.random.normal(jax.random.PRNGKey(5), (3, 4, 8))
    n = embedding.position_perex_sqnorm(g)
    np.testing.assert_allclose(n, jnp.sum(g * g, axis=(1, 2)), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    t=st.sampled_from([2, 4, 8]),
    d=st.sampled_from([4, 8]),
    v=st.sampled_from([3, 7, 32]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_pairwise_vs_onehot(b, t, d, v, seed):
    ids, g = _case(seed, b, t, d, v)
    n0 = embedding.embedding_perex_sqnorm(ids, g)
    _, n1 = ref.embedding_perex_sqnorm_onehot(ids, g, v)
    np.testing.assert_allclose(n0, n1, rtol=1e-3, atol=1e-4)
