"""Linear-layer simultaneous per-example gradient norms vs all oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import linear, ref


def _case(seed, b, t, k, l):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(ks[0], (b, t, k), dtype=jnp.float32)
    g = jax.random.normal(ks[1], (b, t, l), dtype=jnp.float32)
    return x, g


@pytest.mark.parametrize("b,t,k,l", [(2, 4, 8, 8), (3, 8, 16, 8), (1, 2, 4, 12)])
def test_alg1_matches_vmap(b, t, k, l):
    x, g = _case(0, b, t, k, l)
    w, n = linear.linear_gnorm(x, g)
    wr, nr = ref.linear_perex_sqnorm_vmap(x, g)
    np.testing.assert_allclose(w, wr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(n, nr, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("b,t,k,l", [(2, 4, 8, 8), (2, 8, 16, 16)])
def test_alg1_matches_li_etal(b, t, k, l):
    """The simultaneous method and the O(T^2) trick compute the same norm."""
    x, g = _case(1, b, t, k, l)
    w0, n0 = linear.linear_gnorm(x, g)
    w1, n1 = ref.linear_perex_sqnorm_li(x, g)
    np.testing.assert_allclose(w0, w1, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(n0, n1, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "b,t,k,l,bk,bl",
    [(2, 4, 8, 8, 8, 8), (3, 4, 16, 8, 8, 8), (2, 4, 16, 16, 8, 16)],
)
def test_pallas_kernel_matches_einsum(b, t, k, l, bk, bl):
    x, g = _case(2, b, t, k, l)
    w0, n0 = linear.linear_gnorm(x, g)
    w1, n1 = linear.linear_gnorm_pallas(x, g, block_k=bk, block_l=bl)
    np.testing.assert_allclose(w0, w1, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(n0, n1, rtol=1e-4, atol=1e-4)


def test_4d_input_flattened():
    """Extra middle dims (e.g. heads) fold into the contraction."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2, 3, 4, 8))
    g = jax.random.normal(key, (2, 3, 4, 8))
    w, n = linear.linear_gnorm(x, g)
    wr, nr = ref.linear_perex_sqnorm_vmap(
        x.reshape(2, 12, 8), g.reshape(2, 12, 8)
    )
    np.testing.assert_allclose(w, wr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(n, nr, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    t=st.sampled_from([2, 4, 8]),
    k=st.sampled_from([4, 8, 16]),
    l=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_all_three_agree(b, t, k, l, seed):
    x, g = _case(seed, b, t, k, l)
    w0, n0 = linear.linear_gnorm(x, g)
    _, n1 = ref.linear_perex_sqnorm_li(x, g)
    wr, nr = ref.linear_perex_sqnorm_vmap(x, g)
    np.testing.assert_allclose(w0, wr, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(n0, nr, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(n1, nr, rtol=1e-3, atol=1e-4)


def test_flop_formula_crossover():
    """App. E: Li et al. is cheaper only below T = sqrt((2KL-1)/(2K+2L-1))."""
    k = l = 512
    t_star = np.sqrt((2 * k * l - 1) / (2 * k + 2 * l - 1))
    for t, li_cheaper in [(int(t_star * 0.5), True), (int(t_star * 2), False)]:
        f = linear.flops(1, t, k, l)
        assert (f["li_norm"] < f["simultaneous_norm"]) == li_cheaper


def test_io_formula_crossover():
    """App. E: I/O crossover at T = sqrt(2 KL)/2 = sqrt(KL/2)."""
    k = l = 256
    t_star = np.sqrt(k * l / 2.0)
    lo = linear.io_bytes(4, int(t_star * 0.5), k, l)
    hi = linear.io_bytes(4, int(t_star * 2.0), k, l)
    assert lo["li"] < lo["simultaneous"]
    assert hi["li"] > hi["simultaneous"]
