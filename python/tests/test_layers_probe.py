"""Probe-gradient mechanics: each instrumented layer's probe gradient must
equal the sum of per-example squared gradient norms (vmap gold standard)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import layers


def test_linear_probe_carries_perexample_norms():
    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (3, 5, 4))
    w = jax.random.normal(k2, (4, 6))
    b = jax.random.normal(k3, (6,))
    g = jax.random.normal(k4, (3, 5, 6))

    def f(w, b, probe):
        return jnp.sum(layers.gns_linear(x, w, b, probe) * g)

    dw, db, dprobe = jax.grad(f, argnums=(0, 1, 2))(w, b, jnp.zeros(()))

    # gold standard: per-example grads via vmap
    def per_example(xb, gb):
        def fb(w, b):
            return jnp.sum((xb[None] @ w + b) * gb[None])

        return jax.grad(fb, argnums=(0, 1))(w, b)

    dws, dbs = jax.vmap(per_example)(x, g)
    want = float(jnp.sum(dws**2) + jnp.sum(dbs**2))
    np.testing.assert_allclose(float(dprobe), want, rtol=1e-4)
    np.testing.assert_allclose(dw, dws.sum(0), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(db, dbs.sum(0), rtol=1e-4, atol=1e-5)


def test_matmul_probe():
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (2, 4, 8))
    w = jax.random.normal(k2, (8, 3))
    g = jax.random.normal(k3, (2, 4, 3))

    def f(w, probe):
        return jnp.sum(layers.gns_matmul(x, w, probe) * g)

    _, dprobe = jax.grad(f, argnums=(0, 1))(w, jnp.zeros(()))
    wb = jnp.einsum("btk,btl->bkl", x, g)
    want = float(jnp.sum(wb**2))
    np.testing.assert_allclose(float(dprobe), want, rtol=1e-4)


def test_layernorm_probe_variants_agree():
    key = jax.random.PRNGKey(2)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (2, 8, 16))
    gamma = 1.0 + 0.1 * jax.random.normal(k2, (16,))
    beta = 0.1 * jax.random.normal(k3, (16,))
    g = jax.random.normal(k4, (2, 8, 16))

    outs = []
    for ln in (layers.gns_layernorm_xla, layers.gns_layernorm_pallas):
        def f(gamma, beta, probe, ln=ln):
            return jnp.sum(ln(x, gamma, beta, probe) * g)

        grads = jax.grad(f, argnums=(0, 1, 2))(gamma, beta, jnp.zeros(()))
        outs.append(grads)
    for a, b in zip(outs[0], outs[1]):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
    assert float(outs[0][2]) > 0.0


def test_embedding_probe():
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    ids = jax.random.randint(k1, (2, 6), 0, 11)
    wte = jax.random.normal(k2, (11, 4))
    wpe = jax.random.normal(k2, (6, 4))
    g = jax.random.normal(k1, (2, 6, 4))

    def f(wte, wpe, probe):
        return jnp.sum(layers.gns_embedding(ids, wte, wpe, probe) * g)

    _, _, dprobe = jax.grad(f, argnums=(0, 1, 2))(wte, wpe, jnp.zeros(()))

    def per_example(idb, gb):
        def fb(wte, wpe):
            return jnp.sum((wte[idb[None]] + wpe[None, : idb.shape[0]]) * gb[None])

        return jax.grad(fb, argnums=(0, 1))(wte, wpe)

    dwtes, dwpes = jax.vmap(per_example)(ids, g)
    want = float(jnp.sum(dwtes**2) + jnp.sum(dwpes**2))
    np.testing.assert_allclose(float(dprobe), want, rtol=1e-4)


def test_shared_probe_sums_across_layers():
    """Two layers sharing one probe: grads add (per-type aggregation)."""
    key = jax.random.PRNGKey(4)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (2, 3, 4))
    w1 = jax.random.normal(k2, (4, 4))
    w2 = jax.random.normal(k3, (4, 4))

    def f(probe):
        h = layers.gns_matmul(x, w1, probe)
        y = layers.gns_matmul(h, w2, probe)
        return jnp.sum(y**2)

    d_shared = jax.grad(f)(jnp.zeros(()))

    def f2(p1, p2):
        h = layers.gns_matmul(x, w1, p1)
        y = layers.gns_matmul(h, w2, p2)
        return jnp.sum(y**2)

    d1, d2 = jax.grad(f2, argnums=(0, 1))(jnp.zeros(()), jnp.zeros(()))
    np.testing.assert_allclose(float(d_shared), float(d1) + float(d2), rtol=1e-5)


def test_zero_probes_order_matches_stats_order():
    assert set(layers.zero_probes()) == set(layers.STATS_ORDER)
    for v in layers.zero_probes().values():
        assert v.shape == ()
