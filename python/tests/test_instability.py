"""Appendix C.2 teacher–student harness: variants, shapes, step mechanics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import instability as ins

D, H = 16, 2


def _x(seed, b=2, t=8):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, t, D))


def test_init_shapes_and_noise():
    p = ins.init_block(D, 0)
    for got, want in zip(p, ins.param_shapes(D)):
        assert got.shape == want
    noisy = ins.init_block(D, 0, bias_noise=0.05)
    assert not np.allclose(p[3], noisy[3])
    np.testing.assert_allclose(p[2], noisy[2])  # only the bias is perturbed


@pytest.mark.parametrize("variant", ["exact", "lowprec", "cosine"])
def test_forward_shapes(variant):
    p = ins.init_block(D, 1)
    y = ins.block_forward(p, _x(1), H, variant)
    assert y.shape == (2, 8, D)
    assert np.all(np.isfinite(y))


def test_lowprec_differs_from_exact():
    p = ins.init_block(D, 2)
    x = 3.0 * _x(2)  # larger inputs -> visible bf16 rounding
    y_exact = ins.block_forward(p, x, H, "exact")
    y_low = ins.block_forward(p, x, H, "lowprec")
    assert not np.allclose(y_exact, y_low, rtol=1e-6), "bf16 path identical to f32?"
    # but close in absolute terms
    np.testing.assert_allclose(y_exact, y_low, rtol=0.2, atol=0.2)


def test_cosine_bounds_attention_scores():
    p = ins.init_block(D, 3)
    # blow up the qkv weights: cosine attention must stay finite
    p[2] = p[2] * 100.0
    y = ins.block_forward(p, _x(3), H, "cosine")
    assert np.all(np.isfinite(y))


def test_ts_step_reduces_loss():
    teacher = ins.init_block(D, 4)
    student = ins.init_block(D, 4, bias_noise=0.1)
    x = _x(4)
    losses = []
    for _ in range(20):
        out = ins.ts_step(teacher, student, x, jnp.float32(0.5), H, "exact")
        student = list(out[:6])
        losses.append(float(out[6]))
    assert losses[-1] < losses[0] * 0.5, losses[:3] + losses[-3:]


def test_ts_step_metrics():
    teacher = ins.init_block(D, 5)
    student = ins.init_block(D, 5, bias_noise=0.1)
    out = ins.ts_step(teacher, student, _x(5), jnp.float32(0.0), H, "exact")
    # lr=0: student unchanged; dist == initial perturbation norm
    dist = float(out[7])
    want = np.sqrt(sum(np.sum((np.asarray(s) - np.asarray(t)) ** 2)
                       for s, t in zip(student, teacher)))
    np.testing.assert_allclose(dist, want, rtol=1e-5)
