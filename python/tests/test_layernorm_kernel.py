"""Pallas fused LayerNorm kernels vs pure-jnp oracle and jax autodiff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import layernorm as ln
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def _rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def _case(seed, b, t, k):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = _rand(ks[0], b, t, k)
    g = _rand(ks[1], b, t, k)
    gamma = 1.0 + 0.1 * _rand(ks[2], k)
    beta = 0.1 * _rand(ks[3], k)
    return x, g, gamma, beta


@pytest.mark.parametrize("b,t,k", [(2, 8, 16), (3, 12, 32), (1, 4, 8), (4, 16, 64)])
def test_forward_matches_ref(b, t, k):
    x, _, gamma, beta = _case(0, b, t, k)
    y, mean, rstd = ln.layernorm_fwd(x, gamma, beta)
    yr, meanr, rstdr = ref.layernorm_fwd(x, gamma, beta)
    np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(mean, meanr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(rstd, rstdr, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,t,k", [(2, 8, 16), (3, 12, 32), (4, 16, 64)])
@pytest.mark.parametrize("block_t", [None, 4])
def test_backward_matches_ref(b, t, k, block_t):
    x, g, gamma, beta = _case(1, b, t, k)
    _, mean, rstd = ref.layernorm_fwd(x, gamma, beta)
    dx, dgb, dbb, ng, nb = ln.layernorm_bwd_gnorm(x, gamma, mean, rstd, g, block_t=block_t)
    dxr, dgbr, dbbr = ref.layernorm_bwd(x, gamma, mean, rstd, g)
    np.testing.assert_allclose(dx, dxr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dgb, dgbr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dbb, dbbr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(ng, jnp.sum(dgbr**2, -1), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(nb, jnp.sum(dbbr**2, -1), rtol=1e-4, atol=1e-5)


def test_backward_matches_autodiff():
    """The hand-derived backward must equal jax's own vjp of LayerNorm."""
    x, g, gamma, beta = _case(2, 2, 8, 16)

    def f(x, gamma, beta):
        y, _, _ = ref.layernorm_fwd(x, gamma, beta)
        return y

    _, vjp = jax.vjp(f, x, gamma, beta)
    dxr, dgammar, dbetar = vjp(g)
    _, mean, rstd = ref.layernorm_fwd(x, gamma, beta)
    dx, dgb, dbb, _, _ = ln.layernorm_bwd_gnorm(x, gamma, mean, rstd, g)
    np.testing.assert_allclose(dx, dxr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dgb.sum(0), dgammar, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dbb.sum(0), dbetar, rtol=1e-4, atol=1e-4)


def test_perexample_norms_match_vmap_gold_standard():
    """n_b^2 from the fused kernel == norms of vmap'd per-example grads."""
    x, g, gamma, beta = _case(3, 3, 8, 16)

    def per_example(xb, gb):
        def f(gamma, beta):
            y, _, _ = ref.layernorm_fwd(xb[None], gamma, beta)
            return jnp.sum(y * gb[None])

        return jax.grad(f, argnums=(0, 1))(gamma, beta)

    dgammas, dbetas = jax.vmap(per_example)(x, g)
    _, mean, rstd = ref.layernorm_fwd(x, gamma, beta)
    _, _, _, ng, nb = ln.layernorm_bwd_gnorm(x, gamma, mean, rstd, g)
    np.testing.assert_allclose(ng, jnp.sum(dgammas**2, -1), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(nb, jnp.sum(dbetas**2, -1), rtol=1e-4, atol=1e-5)


def test_plain_backward_matches_fused():
    x, g, gamma, beta = _case(4, 2, 16, 32)
    _, mean, rstd = ref.layernorm_fwd(x, gamma, beta)
    dx0, dg0, db0 = ln.layernorm_bwd_plain(x, gamma, mean, rstd, g, block_t=8)
    dx1, dg1, db1, _, _ = ln.layernorm_bwd_gnorm(x, gamma, mean, rstd, g, block_t=8)
    np.testing.assert_allclose(dx0, dx1, rtol=1e-6)
    np.testing.assert_allclose(dg0, dg1, rtol=1e-6)
    np.testing.assert_allclose(db0, db1, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    t=st.sampled_from([4, 6, 8, 16]),
    k=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_shape_sweep(b, t, k, seed):
    x, g, gamma, beta = _case(seed, b, t, k)
    y, mean, rstd = ln.layernorm_fwd(x, gamma, beta)
    yr, _, _ = ref.layernorm_fwd(x, gamma, beta)
    np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-5)
    dx, dgb, dbb, ng, nb = ln.layernorm_bwd_gnorm(x, gamma, mean, rstd, g)
    dxr, dgbr, dbbr = ref.layernorm_bwd(x, gamma, mean, rstd, g)
    np.testing.assert_allclose(dx, dxr, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(ng, jnp.sum(dgbr**2, -1), rtol=1e-3, atol=1e-4)


def test_vmem_estimate_monotone():
    assert ln.vmem_bytes(8, 256, 768) > ln.vmem_bytes(8, 256, 256)
    # norm fusion adds exactly two scalars of VMEM
    assert ln.vmem_bytes(8, 256, 768, fused=True) - ln.vmem_bytes(
        8, 256, 768, fused=False
    ) == 8
