"""L2 model: shapes, loss sanity, and the gold-standard GNS-stats check."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers, model


CFG = model.GPTConfig(name="t", vocab=17, seq_len=8, d_model=16, n_layers=2, n_heads=2)


def _batch(cfg, b, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    ids = jax.random.randint(k1, (b, cfg.seq_len), 0, cfg.vocab)
    tg = jax.random.randint(k2, (b, cfg.seq_len), 0, cfg.vocab)
    return ids, tg


def test_param_spec_counts():
    spec = model.param_spec(CFG)
    # 2 embeddings + 12/block + final ln (2) + lm_head
    assert len(spec) == 2 + 12 * CFG.n_layers + 3
    assert model.n_params(CFG) == sum(int(np.prod(s)) for _, s, _, _ in spec)


def test_init_shapes_and_stats():
    flat = model.init_params(CFG, 0)
    for (name, shape, _, _), p in zip(model.param_spec(CFG), flat):
        assert p.shape == shape, name
    pd = model.params_dict(CFG, flat)
    assert jnp.all(pd["h0.ln1.g"] == 1.0)
    assert jnp.all(pd["h0.attn.qkv.b"] == 0.0)
    # residual projections use the scaled init
    assert pd["h0.attn.proj.w"].std() < pd["h0.attn.qkv.w"].std()


def test_forward_shapes_and_loss():
    flat = model.init_params(CFG, 0)
    ids, tg = _batch(CFG, 3)
    logits = model.forward(CFG, flat, layers.zero_probes(), ids)
    assert logits.shape == (3, CFG.seq_len, CFG.vocab)
    loss = model.loss_fn(CFG, flat, layers.zero_probes(), ids, tg)
    # random init => loss near ln(vocab)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_causality():
    """Changing a future token must not change past logits."""
    flat = model.init_params(CFG, 1)
    ids, _ = _batch(CFG, 1)
    l0 = model.forward(CFG, flat, layers.zero_probes(), ids)
    ids2 = ids.at[0, -1].set((ids[0, -1] + 1) % CFG.vocab)
    l1 = model.forward(CFG, flat, layers.zero_probes(), ids2)
    np.testing.assert_allclose(l0[0, :-1], l1[0, :-1], rtol=1e-5, atol=1e-6)
    assert not np.allclose(l0[0, -1], l1[0, -1])


def test_grad_step_stats_match_vmap_gold_standard():
    """The (5,) stats vector == sum_b ||w'_b||^2 per layer type, with w'_b
    the vmap-materialised per-example gradient of the mean-batch loss."""
    cfg = CFG
    b = 3
    flat = model.init_params(cfg, 2)
    ids, tg = _batch(cfg, b, seed=3)
    loss, grads, stats = model.grad_step(cfg, flat, ids, tg)

    def per_example(idb, tgb):
        def f(fp):
            # mean-batch loss restricted to one example, scaled by 1/b to
            # match w'_b = (1/B) dL_b/dw
            return model.loss_fn(cfg, fp, layers.zero_probes(),
                                 idb[None], tgb[None]) / b

        return jax.grad(f)(flat)

    pex = jax.vmap(per_example)(ids, tg)  # list of (B, *shape)
    want = {k: 0.0 for k in layers.STATS_ORDER}
    for (name, _, ltype, _), gb in zip(model.param_spec(cfg), pex):
        want[ltype] += float(jnp.sum(jnp.square(gb)))
    got = {k: float(s) for k, s in zip(layers.STATS_ORDER, stats)}
    for k in layers.STATS_ORDER:
        np.testing.assert_allclose(got[k], want[k], rtol=2e-3, err_msg=k)

    # and the full gradients agree with the vmap sum
    for (name, _, _, _), g, gb in zip(model.param_spec(cfg), grads, pex):
        np.testing.assert_allclose(g, gb.sum(0), rtol=2e-3, atol=1e-6, err_msg=name)


def test_grad_sqnorms_partition():
    cfg = CFG
    flat = model.init_params(cfg, 4)
    ids, tg = _batch(cfg, 2, seed=5)
    _, grads, _ = model.grad_step(cfg, flat, ids, tg)
    stats = model.grad_sqnorms(cfg, grads)
    total = sum(float(jnp.sum(jnp.square(g))) for g in grads)
    np.testing.assert_allclose(float(stats.sum()), total, rtol=1e-5)


def test_accumulate_and_scale_equals_big_batch():
    """mean of microbatch grads == grad of the concatenated batch."""
    cfg = CFG
    flat = model.init_params(cfg, 6)
    ids, tg = _batch(cfg, 4, seed=7)
    _, g_all, _ = model.grad_step(cfg, flat, ids, tg)
    _, g0, _ = model.grad_step(cfg, flat, ids[:2], tg[:2])
    _, g1, _ = model.grad_step(cfg, flat, ids[2:], tg[2:])
    acc = model.accumulate(g0, g1)
    for a, g in zip(acc, g_all):
        np.testing.assert_allclose(a / 2.0, g, rtol=1e-4, atol=1e-6)


def test_adamw_matches_reference_loop():
    cfg = CFG
    flat = model.init_params(cfg, 8)
    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]
    ids, tg = _batch(cfg, 2, seed=9)
    _, grads, _ = model.grad_step(cfg, flat, ids, tg)
    p2, m2, v2 = model.adamw_update(cfg, flat, m, v, grads,
                                    jnp.float32(1.0), jnp.float32(1e-3),
                                    jnp.float32(1.0))
    # loss decreases after a step on the same batch
    l0 = model.eval_step(cfg, flat, ids, tg)
    l1 = model.eval_step(cfg, p2, ids, tg)
    assert float(l1) < float(l0)
    # weight decay applied only to decayed params
    spec = model.param_spec(cfg)
    iw = [i for i, s in enumerate(spec) if s[0] == "h0.ln1.g"][0]
    # gamma (no decay): update must equal adam step with wd=0
    from compile.kernels import ref
    pg, _, _ = ref.adamw_step(flat[iw], m[iw], v[iw], grads[iw], 1.0, 1e-3, wd=0.0)
    np.testing.assert_allclose(p2[iw], pg, rtol=1e-6)


def test_pallas_and_xla_ln_models_agree():
    cfg_x = CFG
    cfg_p = model.GPTConfig(**{**cfg_x.__dict__, "pallas_ln": True})
    flat = model.init_params(cfg_x, 10)
    ids, tg = _batch(cfg_x, 2, seed=11)
    lx, gx, sx = model.grad_step(cfg_x, flat, ids, tg)
    lp, gp, sp = model.grad_step(cfg_p, flat, ids, tg)
    np.testing.assert_allclose(float(lx), float(lp), rtol=1e-5)
    np.testing.assert_allclose(sx, sp, rtol=1e-4)
    for a, b_ in zip(gx, gp):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-6)


def test_cosine_attention_variant_runs():
    cfg = model.GPTConfig(**{**CFG.__dict__, "cosine_attention": True})
    flat = model.init_params(cfg, 12)
    ids, tg = _batch(cfg, 2, seed=13)
    loss, grads, stats = model.grad_step(cfg, flat, ids, tg)
    assert np.isfinite(float(loss))
    assert all(np.all(np.isfinite(g)) for g in grads)


@pytest.mark.parametrize("name", ["nano", "micro", "small"])
def test_named_configs_consistent(name):
    cfg = model.CONFIGS[name]
    assert cfg.d_model % cfg.n_heads == 0
    assert model.n_params(cfg) > 0


def test_grad_step_plain_matches_instrumented():
    """The ablation baseline must compute identical loss and gradients."""
    cfg = CFG
    flat = model.init_params(cfg, 14)
    ids, tg = _batch(cfg, 2, seed=15)
    l0, g0, _ = model.grad_step(cfg, flat, ids, tg)
    l1, g1 = model.grad_step_plain(cfg, flat, ids, tg)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
    for a, b in zip(g0, g1):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)
