"""AOT exporter: HLO-text lowering and manifest contract."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from compile import aot, layers, model


def test_to_hlo_text_produces_parseable_module():
    lowered = jax.jit(lambda x, y: (x @ y + 2.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32),
        jax.ShapeDtypeStruct((2, 2), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:40]
    assert "dot(" in text or "dot " in text


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entry = aot.export_config(model.CONFIGS["nano"], out)
    return out, entry


def test_export_writes_all_artifacts(exported):
    out, entry = exported
    for rel in entry["artifacts"].values():
        p = out / rel
        assert p.exists() and p.stat().st_size > 100, rel
        assert p.read_text().startswith("HloModule")


def test_manifest_entry_contract(exported):
    _, entry = exported
    cfg = model.CONFIGS["nano"]
    assert entry["n_params"] == model.n_params(cfg)
    assert entry["microbatch"] == aot.MICROBATCH["nano"]
    spec = model.param_spec(cfg)
    assert len(entry["params"]) == len(spec)
    for e, (name, shape, ltype, decay) in zip(entry["params"], spec):
        assert e["name"] == name
        assert tuple(e["shape"]) == shape
        assert e["ltype"] == ltype
        assert e["decay"] == decay
        assert e["ltype"] in layers.STATS_ORDER


def test_manifest_json_is_valid(exported):
    out, entry = exported
    manifest = {
        "schema_version": aot.SCHEMA_VERSION,
        "stats_order": list(layers.STATS_ORDER),
        "configs": {"nano": entry},
        "ln_bench": [],
    }
    text = json.dumps(manifest)
    back = json.loads(text)
    assert back["schema_version"] == 2
    assert back["stats_order"][1] == "layernorm"


def test_stats_order_matches_rust():
    """The canonical order is duplicated in rust/src/lib.rs — keep in sync."""
    lib_rs = Path(__file__).resolve().parents[2] / "rust" / "src" / "lib.rs"
    src = lib_rs.read_text()
    want = ", ".join(f'"{t}"' for t in layers.STATS_ORDER)
    assert want in src, f"rust STATS_ORDER drifted from python: {want}"
