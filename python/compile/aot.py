"""AOT lowering: JAX -> HLO text + manifest.json (the L2 -> L3 contract).

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Artifacts per model config <name> (under artifacts/<name>/):
  init.hlo.txt         (seed:i32[])                         -> params...
  grad_step.hlo.txt    (params..., ids, targets)            -> (loss, grads..., stats[5])
  grad_sqnorms.hlo.txt (grads...)                           -> stats[5]
  accumulate.hlo.txt   (acc..., grads...)                   -> acc...
  adamw_update.hlo.txt (params..., m..., v..., grads...,
                        step, lr, grad_scale)               -> (params..., m..., v...)
  eval_step.hlo.txt    (params..., ids, targets)            -> loss

Plus the Fig. 8 LayerNorm kernel-benchmark artifacts under artifacts/ln_bench/.
Everything a Rust consumer must know (parameter order/shapes/types, stats
layout, microbatch size) is written to artifacts/manifest.json — Rust never
parses HLO.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import layers, model
from .kernels import layernorm as ln_k
from .kernels import ref

SCHEMA_VERSION = 2

#: Microbatch size baked into each config's grad/eval artifacts.
MICROBATCH = {
    "nano": 4,
    "micro": 4,
    "small": 4,
    "sweep70": 4,
    "sweep161": 4,
    "gpt111m": 2,
}

ADAM_HYPERS = {"beta1": 0.9, "beta2": 0.95, "eps": 1e-8, "wd": 0.1}

LN_BENCH_SIZES = [(8, 256, 256), (8, 256, 768), (8, 256, 2048)]  # (B, T, K)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: Path, lowered) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    t0 = time.time()
    path.write_text(to_hlo_text(lowered))
    print(f"  wrote {path} ({path.stat().st_size / 1e6:.2f} MB, {time.time() - t0:.1f}s)")


def export_config(cfg: model.GPTConfig, out: Path) -> dict:
    b = MICROBATCH[cfg.name]
    t = cfg.seq_len
    spec = model.param_spec(cfg)
    p_types = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s, _, _ in spec]
    ids_t = jax.ShapeDtypeStruct((b, t), jnp.int32)
    f32 = jax.ShapeDtypeStruct((), jnp.float32)
    i32 = jax.ShapeDtypeStruct((), jnp.int32)
    d = out / cfg.name
    print(f"config {cfg.name}: {model.n_params(cfg) / 1e6:.2f}M params, microbatch {b}")

    _write(d / "init.hlo.txt",
           jax.jit(lambda seed: tuple(model.init_params(cfg, seed))).lower(i32))
    def gs(*a):
        loss, grads, stats = model.grad_step(cfg, list(a[:-2]), a[-2], a[-1])
        return (loss, *grads, stats)

    _write(d / "grad_step.hlo.txt", jax.jit(gs).lower(*p_types, ids_t, ids_t))

    def gsp(*a):
        loss, grads = model.grad_step_plain(cfg, list(a[:-2]), a[-2], a[-1])
        return (loss, *grads)

    _write(d / "grad_step_plain.hlo.txt", jax.jit(gsp).lower(*p_types, ids_t, ids_t))
    _write(d / "grad_sqnorms.hlo.txt",
           jax.jit(lambda *g: (model.grad_sqnorms(cfg, list(g)),)).lower(*p_types))
    n = len(spec)
    _write(d / "accumulate.hlo.txt",
           jax.jit(lambda *a: tuple(model.accumulate(list(a[:n]), list(a[n:])))
           ).lower(*p_types, *p_types))

    def adam(*a):
        fp, m, v, g = a[:n], a[n:2 * n], a[2 * n:3 * n], a[3 * n:4 * n]
        step, lr, scale = a[4 * n], a[4 * n + 1], a[4 * n + 2]
        np_, nm, nv = model.adamw_update(
            cfg, list(fp), list(m), list(v), list(g), step, lr, scale,
            ADAM_HYPERS["beta1"], ADAM_HYPERS["beta2"], ADAM_HYPERS["eps"],
            ADAM_HYPERS["wd"])
        return (*np_, *nm, *nv)

    _write(d / "adamw_update.hlo.txt",
           jax.jit(adam).lower(*p_types, *p_types, *p_types, *p_types, f32, f32, f32))
    _write(d / "eval_step.hlo.txt",
           jax.jit(lambda *a: (model.eval_step(cfg, list(a[:-2]), a[-2], a[-1]),)
           ).lower(*p_types, ids_t, ids_t))

    return {
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "seq_len": cfg.seq_len,
        "vocab": cfg.vocab,
        "microbatch": b,
        "n_params": model.n_params(cfg),
        "pallas_ln": cfg.pallas_ln,
        "adam": ADAM_HYPERS,
        "params": [
            {"name": nm, "shape": list(s), "dtype": "f32", "ltype": lt, "decay": dc}
            for nm, s, lt, dc in spec
        ],
        "artifacts": {
            k: f"{cfg.name}/{k}.hlo.txt"
            for k in ("init", "grad_step", "grad_step_plain", "grad_sqnorms",
                      "accumulate", "adamw_update", "eval_step")
        },
    }


# ---------------------------------------------------------------------------
# Fig. 8 LayerNorm kernel benchmark artifacts
# ---------------------------------------------------------------------------


def _ln_xla(with_norms: bool):
    def f(x, gamma, beta, g):
        y, mean, rstd = ref.layernorm_fwd(x, gamma, beta)
        if with_norms:
            dx, dg, db, ng, nb = ref.layernorm_bwd_with_norms(x, gamma, mean, rstd, g)
            return (y, dx, dg, db, ng, nb)
        dx, dgb, dbb = ref.layernorm_bwd(x, gamma, mean, rstd, g)
        return (y, dx, dgb.sum(0), dbb.sum(0))

    return f


def _ln_pallas(with_norms: bool):
    def f(x, gamma, beta, g):
        y, mean, rstd = ln_k.layernorm_fwd(x, gamma, beta)
        if with_norms:
            dx, dgb, dbb, ng, nb = ln_k.layernorm_bwd_gnorm(x, gamma, mean, rstd, g)
            return (y, dx, dgb.sum(0), dbb.sum(0), ng, nb)
        dx, dgb, dbb = ln_k.layernorm_bwd_plain(x, gamma, mean, rstd, g)
        return (y, dx, dgb.sum(0), dbb.sum(0))

    return f


def export_ln_bench(out: Path) -> list[dict]:
    entries = []
    for b, t, k in LN_BENCH_SIZES:
        x_t = jax.ShapeDtypeStruct((b, t, k), jnp.float32)
        v_t = jax.ShapeDtypeStruct((k,), jnp.float32)
        variants = {}
        for name, fn in (
            ("xla_plain", _ln_xla(False)),
            ("xla_gnorm", _ln_xla(True)),
            ("pallas_plain", _ln_pallas(False)),
            ("pallas_gnorm", _ln_pallas(True)),
        ):
            rel = f"ln_bench/{name}_k{k}.hlo.txt"
            _write(out / rel, jax.jit(fn).lower(x_t, v_t, v_t, x_t))
            variants[name] = rel
        entries.append({
            "b": b, "t": t, "k": k, "variants": variants,
            "vmem_fused": ln_k.vmem_bytes(b, t, k, fused=True),
            "vmem_plain": ln_k.vmem_bytes(b, t, k, fused=False),
        })
    return entries


# ---------------------------------------------------------------------------
# Appendix C.2 teacher–student instability artifacts (Figs. 11–13)
# ---------------------------------------------------------------------------

TS_SHAPE = {"b": 8, "t": 32, "d": 64, "n_heads": 4, "bias_noise": 0.02}


def export_instability(out: Path) -> dict:
    from . import instability as ins

    d = TS_SHAPE["d"]
    b, t, h = TS_SHAPE["b"], TS_SHAPE["t"], TS_SHAPE["n_heads"]
    p_types = [jax.ShapeDtypeStruct(s, jnp.float32) for s in ins.param_shapes(d)]
    n = len(p_types)
    x_t = jax.ShapeDtypeStruct((b, t, d), jnp.float32)
    f32 = jax.ShapeDtypeStruct((), jnp.float32)
    i32 = jax.ShapeDtypeStruct((), jnp.int32)

    def ts_init(seed):
        teacher = ins.init_block(d, 0, bias_noise=0.0)
        student = ins.init_block(d, 0, bias_noise=TS_SHAPE["bias_noise"])
        # seed folds into the student's noise so Rust can vary it
        key = jax.random.fold_in(jax.random.PRNGKey(1), seed)
        student[3] = ins.init_block(d, 0)[3] + TS_SHAPE["bias_noise"] * jax.random.normal(
            key, (3 * d,), jnp.float32)
        return (*teacher, *student)

    artifacts = {"ts_init": "instability/ts_init.hlo.txt"}
    _write(out / artifacts["ts_init"], jax.jit(ts_init).lower(i32))

    for variant in ("exact", "lowprec", "cosine"):
        def step(*a, _v=variant):
            teacher, student = list(a[:n]), list(a[n:2 * n])
            x, lr = a[2 * n], a[2 * n + 1]
            return ins.ts_step(teacher, student, x, lr, h, _v)

        rel = f"instability/ts_step_{variant}.hlo.txt"
        _write(out / rel, jax.jit(step).lower(*p_types, *p_types, x_t, f32))
        artifacts[f"ts_step_{variant}"] = rel

    return {
        **TS_SHAPE,
        "param_names": ins.PARAM_NAMES,
        "param_shapes": [list(s) for s in ins.param_shapes(d)],
        "artifacts": artifacts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="nano,micro,small,sweep70,sweep161")
    ap.add_argument("--full", action="store_true",
                    help="also export the ~113M-param gpt111m config")
    ap.add_argument("--skip-ln-bench", action="store_true")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    names = [c for c in args.configs.split(",") if c]
    if args.full and "gpt111m" not in names:
        names.append("gpt111m")

    manifest = {
        "schema_version": SCHEMA_VERSION,
        "stats_order": list(layers.STATS_ORDER),
        "configs": {},
        "ln_bench": [],
    }
    for name in names:
        manifest["configs"][name] = export_config(model.CONFIGS[name], out)
    if not args.skip_ln_bench:
        manifest["ln_bench"] = export_ln_bench(out)
    manifest["instability"] = export_instability(out)

    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {out / 'manifest.json'}")


if __name__ == "__main__":
    main()
