"""Appendix C.2 harness: attention numerical-instability teacher–student
experiment (Figs. 11–13), adapted per DESIGN.md §Substitutions.

The paper isolates a flash-attention bf16 divergence by training a
"student" to match a "teacher" (identical weights + small noise on the QKV
bias) and watching the student diverge under the low-precision kernel.
We reproduce the *mechanism* — unbounded q·k magnitudes under reduced-
precision attention arithmetic — by computing the attention scores and
weighted sum in bfloat16 for the "lowprec" student while the "exact"
student stays in float32. Mitigations (cosine attention; the paper's
other option, spectral normalisation, bounds the same quantity) are
exported as their own step variants.

Model: a single pre-LN attention block over continuous inputs (B, T, D).
Training: SGD on MSE(student(x), teacher(x)).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

#: Flat parameter order for the attention block.
PARAM_NAMES = ["ln.g", "ln.b", "qkv.w", "qkv.b", "proj.w", "proj.b"]


def param_shapes(d: int) -> list[tuple[int, ...]]:
    return [(d,), (d,), (d, 3 * d), (3 * d,), (d, d), (d,)]


def init_block(d: int, seed, bias_noise: float = 0.0):
    """Returns the flat parameter list; optionally perturbs the QKV bias
    (the paper's student = teacher + noise on the QKV projection bias)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    params = [
        jnp.ones((d,), jnp.float32),
        jnp.zeros((d,), jnp.float32),
        0.5 / math.sqrt(d) * jax.random.normal(k1, (d, 3 * d), jnp.float32),
        jnp.zeros((3 * d,), jnp.float32),
        0.5 / math.sqrt(d) * jax.random.normal(k2, (d, d), jnp.float32),
        jnp.zeros((d,), jnp.float32),
    ]
    if bias_noise > 0.0:
        params[3] = params[3] + bias_noise * jax.random.normal(k3, (3 * d,), jnp.float32)
    return params


def block_forward(params, x, n_heads: int, variant: str):
    """One pre-LN attention block.

    variant: 'exact' (f32), 'lowprec' (bf16 attention arithmetic — the
    flash-kernel numerics proxy), 'cosine' (normalised q/k, f32).
    """
    g, b, qkv_w, qkv_b, proj_w, proj_b = params
    bs, t, d = x.shape
    dh = d // n_heads
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + 1e-5) * g + b
    qkv = xn @ qkv_w + qkv_b
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(bs, t, n_heads, dh).transpose(0, 2, 1, 3)
    k = k.reshape(bs, t, n_heads, dh).transpose(0, 2, 1, 3)
    v = v.reshape(bs, t, n_heads, dh).transpose(0, 2, 1, 3)
    if variant == "cosine":
        q = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-6)
        k = k / (jnp.linalg.norm(k, axis=-1, keepdims=True) + 1e-6)
        scale = math.sqrt(dh)
    else:
        scale = 1.0 / math.sqrt(dh)
    if variant == "lowprec":
        q, k, v = q.astype(jnp.bfloat16), k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    att = jnp.einsum("bhtd,bhud->bhtu", q, k) * scale
    mask = jnp.tril(jnp.ones((t, t), bool))
    neg = jnp.asarray(-1e9 if variant == "lowprec" else -jnp.inf, att.dtype)
    att = jnp.where(mask, att, neg)
    att = jax.nn.softmax(att, axis=-1)
    y = jnp.einsum("bhtu,bhud->bhtd", att, v).astype(jnp.float32)
    y = y.transpose(0, 2, 1, 3).reshape(bs, t, d)
    return x + (y @ proj_w + proj_b)


def ts_step(teacher, student, x, lr, n_heads: int, variant: str):
    """One SGD step of student-matches-teacher; returns
    (student', loss, dist_to_teacher, qkv_w_norm, qkv_b_norm)."""
    target = block_forward(teacher, x, n_heads, "exact")

    def loss_fn(params):
        out = block_forward(params, x, n_heads, variant)
        return jnp.mean(jnp.square(out - target))

    loss, grads = jax.value_and_grad(loss_fn)(student)
    new_student = [p - lr * gr for p, gr in zip(student, grads)]
    dist = jnp.sqrt(
        sum(jnp.sum(jnp.square(s - t)) for s, t in zip(new_student, teacher))
    )
    qkv_w_norm = jnp.sqrt(jnp.sum(jnp.square(new_student[2])))
    qkv_b_norm = jnp.sqrt(jnp.sum(jnp.square(new_student[3])))
    return (*new_student, loss, dist, qkv_w_norm, qkv_b_norm)
