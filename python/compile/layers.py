"""GNS-instrumented layers: parameter gradients + per-example norms in one
backward pass.

The per-example squared-norm statistics ride out of ``jax.grad`` through
*probe* scalars: each instrumented layer takes an extra scalar input that
does not affect the forward value; its custom_vjp backward returns
``sum_b ||w'_b||^2`` as the probe's "gradient". Probes of the same layer
type are shared, so ``jax.grad`` delivers per-type aggregates for free —
no extra outputs, no host round-trips, exactly one backward pass
(Section 3's "simultaneous" property).

Scaling convention: all norms are of gradients of the *mean-over-batch*
loss, i.e. ``w'_b = (1/B) dL_b/dw``. The B^2 correction of Algorithm 1
step 4 is applied downstream by the Rust coordinator, which knows the
microbatch size.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import embedding as emb_k
from .kernels import layernorm as ln_k
from .kernels import linear as lin_k
from .kernels import ref

# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------


@jax.custom_vjp
def gns_linear(x, w, b, probe):
    """y = x @ w + b with per-example grad sq-norms routed to ``probe``."""
    del probe
    return x @ w + b


def _lin_fwd(x, w, b, probe):
    del probe
    return x @ w + b, (x, w)


def _lin_bwd(res, gy):
    x, w = res
    dx = gy @ w.T
    dw, n_w = lin_k.linear_gnorm(x, gy)
    gy3 = gy.reshape(gy.shape[0], -1, gy.shape[-1])
    db_b = jnp.sum(gy3, axis=1)                       # (B, L) per-example
    db = jnp.sum(db_b, axis=0)
    n_b = jnp.sum(jnp.square(db_b), axis=-1)
    dprobe = jnp.sum(n_w + n_b)
    return dx, dw, db, dprobe


gns_linear.defvjp(_lin_fwd, _lin_bwd)


@jax.custom_vjp
def gns_matmul(x, w, probe):
    """Bias-free variant (lm_head)."""
    del probe
    return x @ w


def _mm_fwd(x, w, probe):
    del probe
    return x @ w, (x, w)


def _mm_bwd(res, gy):
    x, w = res
    dx = gy @ w.T
    dw, n_w = lin_k.linear_gnorm(x, gy)
    return dx, dw, jnp.sum(n_w)


gns_matmul.defvjp(_mm_fwd, _mm_bwd)


# ---------------------------------------------------------------------------
# LayerNorm
# ---------------------------------------------------------------------------


def _make_gns_layernorm(use_pallas: bool):
    @jax.custom_vjp
    def f(x, gamma, beta, probe):
        del probe
        y, _, _ = ref.layernorm_fwd(x, gamma, beta)
        return y

    def fwd(x, gamma, beta, probe):
        del probe
        if use_pallas:
            y, mean, rstd = ln_k.layernorm_fwd(x, gamma, beta)
        else:
            y, mean, rstd = ref.layernorm_fwd(x, gamma, beta)
        return y, (x, gamma, mean, rstd)

    def bwd(res, gy):
        x, gamma, mean, rstd = res
        if use_pallas:
            dx, dgb, dbb, ng, nb = ln_k.layernorm_bwd_gnorm(x, gamma, mean, rstd, gy)
        else:
            dx, dgb, dbb = ref.layernorm_bwd(x, gamma, mean, rstd, gy)
            ng = jnp.sum(jnp.square(dgb), axis=-1)
            nb = jnp.sum(jnp.square(dbb), axis=-1)
        dprobe = jnp.sum(ng + nb)
        return dx, dgb.sum(0), dbb.sum(0), dprobe

    f.defvjp(fwd, bwd)
    return f


#: Fused-Pallas LayerNorm (the paper's Section 5.1 kernel, interpret mode).
gns_layernorm_pallas = _make_gns_layernorm(use_pallas=True)
#: Pure-XLA LayerNorm with the same instrumented backward (Alg. 2 einsums).
gns_layernorm_xla = _make_gns_layernorm(use_pallas=False)


# ---------------------------------------------------------------------------
# Embedding (token + learned position)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def gns_embedding(ids, wte, wpe, probe):
    """wte[ids] + wpe with per-example norms of *both* tables on ``probe``."""
    del probe
    return wte[ids] + wpe[None, : ids.shape[1]]


def _emb_fwd(ids, wte, wpe, probe):
    del probe
    return wte[ids] + wpe[None, : ids.shape[1]], (ids, wte.shape[0], wpe.shape[0])


def _emb_bwd(res, gy):
    ids, vocab, t_max = res
    dwte = emb_k.embedding_grad(ids, gy, vocab)
    n_wte = emb_k.embedding_perex_sqnorm(ids, gy)
    t = ids.shape[1]
    dwpe = jnp.zeros((t_max, gy.shape[-1]), gy.dtype).at[:t].set(gy.sum(axis=0))
    n_wpe = emb_k.position_perex_sqnorm(gy)
    dprobe = jnp.sum(n_wte + n_wpe)
    return None, dwte, dwpe, dprobe


gns_embedding.defvjp(_emb_fwd, _emb_bwd)


def zero_probes():
    """One probe scalar per layer-type, in the canonical stats order."""
    return {
        "embedding": jnp.zeros(()),
        "layernorm": jnp.zeros(()),
        "attention": jnp.zeros(()),
        "mlp": jnp.zeros(()),
        "lm_head": jnp.zeros(()),
    }


#: Canonical order of the stats vector crossing the L2->L3 boundary.
STATS_ORDER = ("embedding", "layernorm", "attention", "mlp", "lm_head")
