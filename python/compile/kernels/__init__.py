"""L1: Pallas kernels + einsum algorithms for simultaneous per-example
gradient norms (paper Section 3 + Section 5.1), validated against ref.py."""

from . import embedding, layernorm, linear, ref  # noqa: F401
