"""Pallas fused LayerNorm kernels with simultaneous per-example grad norms.

This is the TPU/Pallas adaptation of the paper's Section 5.1 CUDA kernel
("normgnorm"): a LayerNorm backward pass that *also* emits the per-example
squared gradient norms of gamma and beta at zero additional memory traffic.

CUDA -> Pallas mapping (DESIGN.md §Hardware-Adaptation):

* threadblock per row-group        -> grid = (B, T // block_t); one program
  owns a (block_t, K) tile of one example, resident in VMEM.
* warp reduce + shared-mem atomics -> vector-unit reductions over the lane
  (K) and sublane (T) axes of the VMEM tile; no atomics are needed because
  TPU grids execute sequentially over the last axis, so cross-tile
  accumulation uses block revisiting on the (B, K) output.
* "free" per-example norm          -> the rows g and g*xhat are already in
  registers/VMEM for dgamma/dbeta; squaring the (B, K) accumulator on the
  final sequence tile adds zero HBM traffic.

All entry points run with ``interpret=True`` (the CPU PJRT plugin cannot
execute Mosaic custom-calls); on a real TPU the same BlockSpecs express the
HBM<->VMEM schedule.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_block(t: int, preferred: int = 128) -> int:
    """Largest divisor of ``t`` no bigger than ``preferred``."""
    b = min(t, preferred)
    while t % b:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _ln_fwd_kernel(x_ref, gamma_ref, beta_ref, y_ref, mean_ref, rstd_ref, *, eps):
    x = x_ref[0]  # (block_t, K)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = (x - mean) * rstd
    y_ref[0] = xhat * gamma_ref[...] + beta_ref[...]
    mean_ref[0] = mean[:, 0]
    rstd_ref[0] = rstd[:, 0]


def layernorm_fwd(x, gamma, beta, eps: float = 1e-5, block_t: int | None = None):
    """Fused LayerNorm forward. Returns (y, mean, rstd).

    x: (B, T, K); gamma, beta: (K,). mean/rstd: (B, T), saved for backward —
    a single HBM pass over x, emitting 2 extra scalars per row.
    """
    b, t, k = x.shape
    bt = block_t or _round_block(t)
    grid = (b, t // bt)
    return pl.pallas_call(
        functools.partial(_ln_fwd_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((k,), lambda i, j: (0,)),
            pl.BlockSpec((k,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bt), lambda i, j: (i, j)),
            pl.BlockSpec((1, bt), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, k), x.dtype),
            jax.ShapeDtypeStruct((b, t), x.dtype),
            jax.ShapeDtypeStruct((b, t), x.dtype),
        ],
        interpret=True,
    )(x, gamma, beta)


# ---------------------------------------------------------------------------
# Fused backward + per-example gradient norms (the paper's kernel)
# ---------------------------------------------------------------------------


def _ln_bwd_kernel(
    x_ref, gamma_ref, mean_ref, rstd_ref, g_ref,
    dx_ref, dgamma_b_ref, dbeta_b_ref, ngamma_ref, nbeta_ref,
    *, nt: int,
):
    j = pl.program_id(1)  # sequence-tile index; axis is sequential on TPU

    x = x_ref[0]          # (block_t, K)
    g = g_ref[0]
    mean = mean_ref[0][:, None]
    rstd = rstd_ref[0][:, None]
    gamma = gamma_ref[...]

    xhat = (x - mean) * rstd
    ggam = g * gamma
    c1 = jnp.mean(ggam, axis=-1, keepdims=True)
    c2 = jnp.mean(ggam * xhat, axis=-1, keepdims=True)
    dx_ref[0] = (ggam - c1 - xhat * c2) * rstd

    # Partial per-example parameter grads for this sequence tile: the rows
    # g and g*xhat are already live — the reduction over the tile is free.
    pg = jnp.sum(g * xhat, axis=0)  # (K,) partial dgamma_b
    pb = jnp.sum(g, axis=0)         # (K,) partial dbeta_b

    # Accumulate across sequence tiles by revisiting the (1, K) block.
    @pl.when(j == 0)
    def _init():
        dgamma_b_ref[0] = pg
        dbeta_b_ref[0] = pb

    @pl.when(j > 0)
    def _acc():
        dgamma_b_ref[0] += pg
        dbeta_b_ref[0] += pb

    # On the final tile the full per-example K-vectors are resident in
    # VMEM; the squared norm is a lane reduction — zero extra HBM traffic.
    @pl.when(j == nt - 1)
    def _norms():
        ngamma_ref[0] = jnp.sum(jnp.square(dgamma_b_ref[0]))
        nbeta_ref[0] = jnp.sum(jnp.square(dbeta_b_ref[0]))


def layernorm_bwd_gnorm(x, gamma, mean, rstd, g, block_t: int | None = None):
    """Fused LayerNorm backward emitting per-example grad sq-norms (Alg. 2).

    Args match ref.layernorm_bwd. Returns
    ``(dx, dgamma_b, dbeta_b, ngamma_sq, nbeta_sq)`` with shapes
    ``(B,T,K), (B,K), (B,K), (B,), (B,)``. The total dgamma/dbeta are the
    (cheap) batch-sums of the per-example tensors.
    """
    b, t, k = x.shape
    bt = block_t or _round_block(t)
    nt = t // bt
    grid = (b, nt)
    return pl.pallas_call(
        functools.partial(_ln_bwd_kernel, nt=nt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((k,), lambda i, j: (0,)),
            pl.BlockSpec((1, bt), lambda i, j: (i, j)),
            pl.BlockSpec((1, bt), lambda i, j: (i, j)),
            pl.BlockSpec((1, bt, k), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, k), x.dtype),
            jax.ShapeDtypeStruct((b, k), x.dtype),
            jax.ShapeDtypeStruct((b, k), x.dtype),
            jax.ShapeDtypeStruct((b,), x.dtype),
            jax.ShapeDtypeStruct((b,), x.dtype),
        ],
        interpret=True,
    )(x, gamma, mean, rstd, g)


def _ln_bwd_plain_kernel(
    x_ref, gamma_ref, mean_ref, rstd_ref, g_ref,
    dx_ref, dgamma_b_ref, dbeta_b_ref,
):
    """Baseline backward without the norm fusion — the Fig. 8 comparator."""
    j = pl.program_id(1)
    x = x_ref[0]
    g = g_ref[0]
    mean = mean_ref[0][:, None]
    rstd = rstd_ref[0][:, None]
    gamma = gamma_ref[...]
    xhat = (x - mean) * rstd
    ggam = g * gamma
    c1 = jnp.mean(ggam, axis=-1, keepdims=True)
    c2 = jnp.mean(ggam * xhat, axis=-1, keepdims=True)
    dx_ref[0] = (ggam - c1 - xhat * c2) * rstd
    pg = jnp.sum(g * xhat, axis=0)
    pb = jnp.sum(g, axis=0)

    @pl.when(j == 0)
    def _init():
        dgamma_b_ref[0] = pg
        dbeta_b_ref[0] = pb

    @pl.when(j > 0)
    def _acc():
        dgamma_b_ref[0] += pg
        dbeta_b_ref[0] += pb


def layernorm_bwd_plain(x, gamma, mean, rstd, g, block_t: int | None = None):
    """LayerNorm backward without per-example norms (baseline for Fig. 8)."""
    b, t, k = x.shape
    bt = block_t or _round_block(t)
    grid = (b, t // bt)
    return pl.pallas_call(
        _ln_bwd_plain_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((k,), lambda i, j: (0,)),
            pl.BlockSpec((1, bt), lambda i, j: (i, j)),
            pl.BlockSpec((1, bt), lambda i, j: (i, j)),
            pl.BlockSpec((1, bt, k), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, k), x.dtype),
            jax.ShapeDtypeStruct((b, k), x.dtype),
            jax.ShapeDtypeStruct((b, k), x.dtype),
        ],
        interpret=True,
    )(x, gamma, mean, rstd, g)


def vmem_bytes(b: int, t: int, k: int, block_t: int | None = None,
               dtype_bytes: int = 4, fused: bool = True) -> int:
    """Estimated peak VMEM residency per grid step of the backward kernel.

    Used by the §Perf analysis: inputs x, g tiles + saved stats + gamma +
    dx tile + the (1, K) accumulators (norm fusion adds only two scalars).
    """
    bt = block_t or _round_block(t)
    tile = bt * k * dtype_bytes
    stats = 2 * bt * dtype_bytes
    acc = 2 * k * dtype_bytes
    scalars = 2 * dtype_bytes if fused else 0
    return 3 * tile + stats + k * dtype_bytes + acc + scalars
