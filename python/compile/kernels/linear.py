"""Simultaneous per-example gradient norms for linear layers (paper Alg. 1).

Two implementations with identical contracts:

* :func:`linear_gnorm` — the einsum form of Algorithm 1, exactly as the
  paper presents it ("einsum for readability and portability"). XLA fuses
  the square-and-reduce into the batched matmul epilogue; this is what the
  L2 model uses so it lowers into the train-step HLO.
* :func:`linear_gnorm_pallas` — a tiled Pallas kernel demonstrating the
  same computation as an explicit HBM<->VMEM schedule: grid over
  (K-tiles, L-tiles, B); each program computes a (bk, bl) tile of the
  per-example outer-product gradient w'_b on the MXU, accumulates it into
  the shared weight-gradient tile (block revisiting over the batch axis)
  and folds its squared sum into the per-example scalar (block revisiting
  over the tile axes) — the intermediate w'_b tile never leaves VMEM,
  which is the FLOP/IO win of Section 3.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def linear_gnorm(x, g):
    """Algorithm 1: returns (w', n_sq) = ((K, L) grad, (B,) per-ex sq-norms).

    x: (B, T, K) input activations; g: (B, T, L) output cotangents. Any
    number of middle dims is supported by flattening to one.
    """
    x3 = x.reshape(x.shape[0], -1, x.shape[-1])
    g3 = g.reshape(g.shape[0], -1, g.shape[-1])
    wb = jnp.einsum("btk,btl->bkl", x3, g3)
    n_sq = jnp.einsum("bkl,bkl->b", wb, wb)
    w = jnp.einsum("bkl->kl", wb)
    return w, n_sq


def _round_block(n: int, preferred: int) -> int:
    b = min(n, preferred)
    while n % b:
        b -= 1
    return b


def _linear_gnorm_kernel(x_ref, g_ref, w_ref, nsq_ref):
    i = pl.program_id(0)  # K-tile
    j = pl.program_id(1)  # L-tile
    b = pl.program_id(2)  # example (fastest axis)
    # (T, bk) x (T, bl) -> (bk, bl) per-example gradient tile on the MXU.
    wb = jnp.einsum(
        "tk,tl->kl", x_ref[0], g_ref[0], preferred_element_type=jnp.float32
    )
    sq = jnp.sum(jnp.square(wb))

    # Weight-gradient tile (i, j) is revisited across the b sweep.
    @pl.when(b == 0)
    def _w_init():
        w_ref[...] = wb

    @pl.when(b > 0)
    def _w_acc():
        w_ref[...] += wb

    # Per-example scalar block (b,) is revisited across (i, j) sweeps.
    @pl.when((i == 0) & (j == 0))
    def _n_init():
        nsq_ref[0] = sq

    @pl.when((i > 0) | (j > 0))
    def _n_acc():
        nsq_ref[0] += sq


def linear_gnorm_pallas(x, g, block_k: int = 128, block_l: int = 128):
    """Pallas form of Algorithm 1. Same contract as :func:`linear_gnorm`.

    Grid (K-tiles, L-tiles, B) — batch innermost so the (bk, bl) weight
    tile stays VMEM-resident while every example's contribution is
    accumulated; TPU grid axes execute sequentially, so block revisiting
    replaces the CUDA kernel's atomics.
    """
    bsz, t, k = x.shape
    l = g.shape[-1]
    bk = _round_block(k, block_k)
    bl = _round_block(l, block_l)
    grid = (k // bk, l // bl, bsz)
    w, nsq = pl.pallas_call(
        _linear_gnorm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, bk), lambda i, j, b: (b, 0, i)),
            pl.BlockSpec((1, t, bl), lambda i, j, b: (b, 0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bk, bl), lambda i, j, b: (i, j)),
            pl.BlockSpec((1,), lambda i, j, b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, l), jnp.float32),
            jax.ShapeDtypeStruct((bsz,), jnp.float32),
        ],
        interpret=True,
    )(x, g)
    return w.astype(x.dtype), nsq.astype(x.dtype)


def flops(b: int, t: int, k: int, l: int) -> dict:
    """Table 1 FLOP formulae for one linear layer (both algorithms)."""
    return {
        "simultaneous_grad": b * k * l * (2 * t - 1) + k * l * (b - 1),
        "simultaneous_norm": b * k * l + b * (k * l - 1),
        "li_grad": k * l * (2 * b * t - 1),
        "li_norm": b * t * t * (2 * k + 2 * l - 2) + b * t * t,
    }


def io_bytes(b: int, t: int, k: int, l: int, bytes_per: int = 4) -> dict:
    """Table 2 I/O formulae for one linear layer (both algorithms)."""
    return {
        "simultaneous": (b * k * l + b * k * t + b * l * t + b * k * l + b) * bytes_per,
        "li": (b * k * t + b * l * t + k * l + 2 * b * t * t + b) * bytes_per,
    }
