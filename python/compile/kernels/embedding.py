"""Per-example gradient norms for embedding layers (paper Alg. 3).

Algorithm 3 materialises a (B, V, D) one-hot contraction — fine as an
oracle, hopeless for a real vocabulary. The production path here uses the
Gram identity

    n_b^2 = || sum_t onehot(x_bt) g_bt ||^2
          = sum_{t,u} 1[x_bt == x_bu] <g_bt, g_bu>,

which needs O(B T^2) memory instead of O(B V D) and lowers to two batched
matmuls. The weight gradient itself is the ordinary scatter-add that
``jax.grad`` already produces for a gather, so only the norm is computed
here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_perex_sqnorm(ids, g):
    """(B,) per-example squared grad norms of an embedding table.

    ids: (B, T) int32 token ids; g: (B, T, D) cotangent of gathered rows.
    """
    same = (ids[:, :, None] == ids[:, None, :]).astype(g.dtype)
    gram = jnp.einsum("btd,bud->btu", g, g)
    return jnp.einsum("btu,btu->b", same, gram)


def embedding_grad(ids, g, vocab: int):
    """(V, D) embedding gradient via scatter-add (segment sum over ids)."""
    d = g.shape[-1]
    return jax.ops.segment_sum(
        g.reshape(-1, d), ids.reshape(-1), num_segments=vocab
    )


def position_perex_sqnorm(g):
    """Per-example sq-norm for a positional-embedding table wpe (T, D).

    Each position row is hit exactly once per example, so the per-example
    gradient is just g_b and its squared norm a plain reduction.
    """
    return jnp.sum(jnp.square(g), axis=(1, 2))
