"""Pure-jnp correctness oracles for the GNS kernels.

Every Pallas kernel and every einsum "simultaneous per-example gradient
norm" algorithm in this package is validated against the functions here.
Two kinds of oracle are provided:

1. Analytic closed forms (LayerNorm forward/backward written out by hand).
2. The *gold standard*: per-example gradients materialised explicitly with
   ``jax.vmap(jax.grad(...))``, the definitionally-correct but expensive
   route (Goodfellow [26]'s motivation).

Shapes follow the paper (Section 3): activations are ``(B, T, K)`` with
batch B, sequence T, feature K; linear weights are ``(K, L)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# LayerNorm
# ---------------------------------------------------------------------------


def layernorm_fwd(x, gamma, beta, eps: float = 1e-5):
    """LayerNorm forward. Returns (y, mean, rstd) with mean/rstd saved for bwd.

    x: (..., K); gamma, beta: (K,).
    """
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = (x - mean) * rstd
    return xhat * gamma + beta, mean[..., 0], rstd[..., 0]


def layernorm_bwd(x, gamma, mean, rstd, g):
    """Hand-derived LayerNorm backward.

    Args:
      x: (B, T, K) input activations.
      gamma: (K,) scale.
      mean, rstd: (B, T) statistics saved from the forward pass.
      g: (B, T, K) cotangent of the output.

    Returns:
      dx: (B, T, K)
      dgamma_b: (B, K) per-example gamma gradients (sum over T only)
      dbeta_b:  (B, K) per-example beta gradients
    """
    xhat = (x - mean[..., None]) * rstd[..., None]
    ggam = g * gamma
    c1 = jnp.mean(ggam, axis=-1, keepdims=True)
    c2 = jnp.mean(ggam * xhat, axis=-1, keepdims=True)
    dx = (ggam - c1 - xhat * c2) * rstd[..., None]
    dgamma_b = jnp.einsum("btk,btk->bk", g, xhat)
    dbeta_b = jnp.einsum("btk->bk", g)
    return dx, dgamma_b, dbeta_b


def layernorm_bwd_with_norms(x, gamma, mean, rstd, g):
    """Backward plus the paper's per-example squared gradient norms (Alg. 2).

    Returns (dx, dgamma, dbeta, ngamma_sq, nbeta_sq) where the n*_sq are
    (B,) vectors of per-example squared norms *without* the B^2 correction
    (the caller owns loss-scaling conventions).
    """
    dx, dgamma_b, dbeta_b = layernorm_bwd(x, gamma, mean, rstd, g)
    ngamma_sq = jnp.sum(jnp.square(dgamma_b), axis=-1)
    nbeta_sq = jnp.sum(jnp.square(dbeta_b), axis=-1)
    return dx, dgamma_b.sum(0), dbeta_b.sum(0), ngamma_sq, nbeta_sq


# ---------------------------------------------------------------------------
# Linear layer per-example gradient norms
# ---------------------------------------------------------------------------


def linear_perex_sqnorm_simultaneous(x, g):
    """Paper Algorithm 1: materialise w'_b, reduce. O(B*K*L) memory.

    x: (B, T, K) activations into the linear layer.
    g: (B, T, L) cotangents of the output.
    Returns (w', n_sq) with w' = (K, L) weight gradient and n_sq = (B,)
    per-example squared norms.
    """
    wb = jnp.einsum("btk,btl->bkl", x, g)
    n_sq = jnp.einsum("bkl,bkl->b", wb, wb)
    w = jnp.einsum("bkl->kl", wb)
    return w, n_sq


def linear_perex_sqnorm_li(x, g):
    """Li et al. [36] O(T^2) trick: <X X^T, G G^T>_F per example.

    Same contract as :func:`linear_perex_sqnorm_simultaneous`; used as the
    baseline comparator in the cost-model figures and as a second oracle.
    """
    xxt = jnp.einsum("btk,buk->btu", x, x)
    ggt = jnp.einsum("btl,bul->btu", g, g)
    n_sq = jnp.einsum("btu,btu->b", xxt, ggt)
    w = jnp.einsum("btk,btl->kl", x, g)
    return w, n_sq


def linear_perex_sqnorm_vmap(x, g):
    """Gold standard: explicit per-example outer products via vmap."""
    wb = jax.vmap(lambda xb, gb: xb.T @ gb)(x, g)
    n_sq = jax.vmap(lambda w: jnp.sum(w * w))(wb)
    return wb.sum(0), n_sq


# ---------------------------------------------------------------------------
# Embedding per-example gradient norms
# ---------------------------------------------------------------------------


def embedding_perex_sqnorm_onehot(ids, g, vocab: int):
    """Paper Algorithm 3: one-hot einsum. O(B*V*D) memory — oracle only.

    ids: (B, T) int32 token ids; g: (B, T, D) cotangents of the gathered rows.
    Returns (w', n_sq): (V, D) embedding gradient and (B,) per-example
    squared norms.
    """
    o = jax.nn.one_hot(ids, vocab, dtype=g.dtype)
    wb = jnp.einsum("btv,btd->bvd", o, g)
    n_sq = jnp.einsum("bvd,bvd->b", wb, wb)
    return wb.sum(0), n_sq


def embedding_perex_sqnorm_pairwise(ids, g):
    """Memory-lean equivalent used in the model: the norm only needs the
    Gram structure, n_b^2 = sum_{t,u} 1[x_bt == x_bu] <g_bt, g_bu>.

    O(B*T^2*D) FLOPs but O(B*T^2) memory — no V-sized intermediate.
    """
    same = (ids[:, :, None] == ids[:, None, :]).astype(g.dtype)
    gram = jnp.einsum("btd,bud->btu", g, g)
    return jnp.einsum("btu,btu->b", same, gram)


# ---------------------------------------------------------------------------
# Optimizer oracle
# ---------------------------------------------------------------------------


def adamw_step(p, m, v, grad, step, lr, beta1=0.9, beta2=0.95, eps=1e-8, wd=0.1):
    """Reference AdamW (decoupled weight decay), bias-corrected.

    ``step`` is the 1-based step index *after* this update.
    """
    m = beta1 * m + (1.0 - beta1) * grad
    v = beta2 * v + (1.0 - beta2) * jnp.square(grad)
    mhat = m / (1.0 - beta1**step)
    vhat = v / (1.0 - beta2**step)
    p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    return p, m, v
