"""L2: GNS-instrumented GPT (nanoGPT-style decoder) in JAX.

Every parameterised sub-layer goes through the instrumented layers of
``layers.py``, so a single backward pass yields the parameter gradients
*and* the per-layer-type per-example gradient-norm statistics (paper
Section 3). The module also defines the AdamW update, init, and eval
functions that ``aot.py`` lowers to HLO text for the Rust coordinator.

Model family follows Cerebras-GPT / nanoGPT: pre-LN blocks, GELU MLP with
4x expansion, learned positional embeddings, untied byte-level LM head.
Optional stability variants from Appendix C.2: cosine attention and
spectrally-normalised QKV projections (per-block flags).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from . import layers
from .kernels import ref

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GPTConfig:
    name: str = "nano"
    vocab: int = 256
    seq_len: int = 64
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    eps: float = 1e-5
    # Use the Pallas fused LayerNorm inside the model (numerically identical
    # to the XLA path; interpret-mode loops make it slow on CPU, so large
    # configs default to the XLA einsum form of Alg. 2).
    pallas_ln: bool = False
    # Appendix C.2 mitigations, applied to every block when set.
    cosine_attention: bool = False
    qk_scale: float | None = None  # temperature for cosine attention

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model


#: Named configs. "gpt111m" mirrors the paper's hidden size 768 family,
#: with layers chosen so the byte-vocab model lands at ~113M parameters.
CONFIGS = {
    "nano": GPTConfig(name="nano", vocab=256, seq_len=64, d_model=64, n_layers=2, n_heads=2, pallas_ln=True),
    "micro": GPTConfig(name="micro", vocab=256, seq_len=128, d_model=128, n_layers=4, n_heads=4),
    "small": GPTConfig(name="small", vocab=256, seq_len=128, d_model=192, n_layers=6, n_heads=6),
    # Fig. 10 Chinchilla sweep companions to "small" (hidden-size varied).
    "sweep70": GPTConfig(name="sweep70", vocab=256, seq_len=128, d_model=144, n_layers=6, n_heads=6),
    "sweep161": GPTConfig(name="sweep161", vocab=256, seq_len=128, d_model=240, n_layers=6, n_heads=6),
    "gpt111m": GPTConfig(name="gpt111m", vocab=256, seq_len=256, d_model=768, n_layers=16, n_heads=12),
}


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_spec(cfg: GPTConfig) -> list[tuple[str, tuple[int, ...], str, bool]]:
    """Flat parameter layout: (name, shape, layer_type, weight_decay).

    This exact order is the artifact calling convention; it is serialised
    into manifest.json and must never be reordered silently.
    """
    d, v, t, f = cfg.d_model, cfg.vocab, cfg.seq_len, cfg.d_ff
    spec: list[tuple[str, tuple[int, ...], str, bool]] = [
        ("wte", (v, d), "embedding", True),
        ("wpe", (t, d), "embedding", True),
    ]
    for i in range(cfg.n_layers):
        p = f"h{i}."
        spec += [
            (p + "ln1.g", (d,), "layernorm", False),
            (p + "ln1.b", (d,), "layernorm", False),
            (p + "attn.qkv.w", (d, 3 * d), "attention", True),
            (p + "attn.qkv.b", (3 * d,), "attention", False),
            (p + "attn.proj.w", (d, d), "attention", True),
            (p + "attn.proj.b", (d,), "attention", False),
            (p + "ln2.g", (d,), "layernorm", False),
            (p + "ln2.b", (d,), "layernorm", False),
            (p + "mlp.fc.w", (d, f), "mlp", True),
            (p + "mlp.fc.b", (f,), "mlp", False),
            (p + "mlp.proj.w", (f, d), "mlp", True),
            (p + "mlp.proj.b", (d,), "mlp", False),
        ]
    spec += [
        ("lnf.g", (d,), "layernorm", False),
        ("lnf.b", (d,), "layernorm", False),
        ("lm_head.w", (d, v), "lm_head", True),
    ]
    return spec


def n_params(cfg: GPTConfig) -> int:
    return sum(math.prod(s) for _, s, _, _ in param_spec(cfg))


def init_params(cfg: GPTConfig, seed) -> list[jnp.ndarray]:
    """GPT-2 init: N(0, 0.02), residual projections scaled by 1/sqrt(2L)."""
    key = jax.random.PRNGKey(seed)
    spec = param_spec(cfg)
    keys = jax.random.split(key, len(spec))
    out = []
    resid_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    for k, (name, shape, _, _) in zip(keys, spec):
        if name.endswith((".g",)):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".b",)) and len(shape) == 1:
            out.append(jnp.zeros(shape, jnp.float32))
        elif name.endswith("proj.w"):
            out.append(resid_scale * jax.random.normal(k, shape, jnp.float32))
        else:
            out.append(0.02 * jax.random.normal(k, shape, jnp.float32))
    return out


def params_dict(cfg: GPTConfig, flat: list[jnp.ndarray]) -> Params:
    return {name: p for (name, _, _, _), p in zip(param_spec(cfg), flat)}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _attention(cfg: GPTConfig, pd: Params, probes, x, prefix: str):
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    qkv = layers.gns_linear(
        x, pd[prefix + "attn.qkv.w"], pd[prefix + "attn.qkv.b"], probes["attention"]
    )
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    if cfg.cosine_attention:
        # App. C.2 mitigation: normalise q/k head vectors before attention.
        q = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-6)
        k = k / (jnp.linalg.norm(k, axis=-1, keepdims=True) + 1e-6)
        scale = cfg.qk_scale if cfg.qk_scale is not None else math.sqrt(dh)
    else:
        scale = 1.0 / math.sqrt(dh)
    att = jnp.einsum("bhtd,bhud->bhtu", q, k) * scale
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask, att, -jnp.inf)
    att = jax.nn.softmax(att, axis=-1)
    y = jnp.einsum("bhtu,bhud->bhtd", att, v)
    y = y.transpose(0, 2, 1, 3).reshape(b, t, d)
    return layers.gns_linear(
        y, pd[prefix + "attn.proj.w"], pd[prefix + "attn.proj.b"], probes["attention"]
    )


def forward(cfg: GPTConfig, flat_params, probes, ids):
    """Logits for token ids (B, T) -> (B, T, V)."""
    pd = params_dict(cfg, flat_params)
    ln = layers.gns_layernorm_pallas if cfg.pallas_ln else layers.gns_layernorm_xla
    x = layers.gns_embedding(ids, pd["wte"], pd["wpe"], probes["embedding"])
    for i in range(cfg.n_layers):
        p = f"h{i}."
        xn = ln(x, pd[p + "ln1.g"], pd[p + "ln1.b"], probes["layernorm"])
        x = x + _attention(cfg, pd, probes, xn, p)
        xn = ln(x, pd[p + "ln2.g"], pd[p + "ln2.b"], probes["layernorm"])
        hdn = layers.gns_linear(xn, pd[p + "mlp.fc.w"], pd[p + "mlp.fc.b"], probes["mlp"])
        hdn = jax.nn.gelu(hdn, approximate=True)
        x = x + layers.gns_linear(
            hdn, pd[p + "mlp.proj.w"], pd[p + "mlp.proj.b"], probes["mlp"]
        )
    x = ln(x, pd["lnf.g"], pd["lnf.b"], probes["layernorm"])
    return layers.gns_matmul(x, pd["lm_head.w"], probes["lm_head"])


def loss_fn(cfg: GPTConfig, flat_params, probes, ids, targets):
    """Mean cross-entropy over (B, T)."""
    logits = forward(cfg, flat_params, probes, ids)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


# ---------------------------------------------------------------------------
# Train-step functions (lowered to HLO by aot.py)
# ---------------------------------------------------------------------------


def grad_step(cfg: GPTConfig, flat_params, ids, targets):
    """One microbatch fwd+bwd.

    Returns (loss, grads, stats) where stats is a (5,) f32 vector of
    ``sum_b ||w'_b||^2`` per layer type in layers.STATS_ORDER — the
    per-example component of the GNS estimators. The B^2/B correction and
    EMA smoothing happen in the Rust coordinator.
    """
    probes = layers.zero_probes()

    def f(fp, pr):
        return loss_fn(cfg, fp, pr, ids, targets)

    loss, (grads, probe_grads) = jax.value_and_grad(f, argnums=(0, 1))(
        flat_params, probes
    )
    stats = jnp.stack([probe_grads[k] for k in layers.STATS_ORDER])
    return loss, grads, stats


def grad_step_plain(cfg: GPTConfig, flat_params, ids, targets):
    """Ablation baseline for Section 5.1: the same fwd+bwd *without* any
    per-example instrumentation (plain jnp layers, no probes). Used by the
    instrumentation bench to measure the true cost of GNS tracking."""

    def plain_forward(fp):
        pd = params_dict(cfg, fp)
        from .kernels import ref as _ref

        def ln(x, g, b):
            y, _, _ = _ref.layernorm_fwd(x, g, b)
            return y

        x = pd["wte"][ids] + pd["wpe"][None, : ids.shape[1]]
        for i in range(cfg.n_layers):
            p = f"h{i}."
            xn = ln(x, pd[p + "ln1.g"], pd[p + "ln1.b"])
            b, t, d = xn.shape
            h, dh = cfg.n_heads, cfg.d_head
            qkv = xn @ pd[p + "attn.qkv.w"] + pd[p + "attn.qkv.b"]
            q, k, v = jnp.split(qkv, 3, axis=-1)
            q = q.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
            k = k.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
            v = v.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
            att = jnp.einsum("bhtd,bhud->bhtu", q, k) / math.sqrt(dh)
            mask = jnp.tril(jnp.ones((t, t), bool))
            att = jax.nn.softmax(jnp.where(mask, att, -jnp.inf), axis=-1)
            y = jnp.einsum("bhtu,bhud->bhtd", att, v)
            y = y.transpose(0, 2, 1, 3).reshape(b, t, d)
            x = x + (y @ pd[p + "attn.proj.w"] + pd[p + "attn.proj.b"])
            xn = ln(x, pd[p + "ln2.g"], pd[p + "ln2.b"])
            hdn = jax.nn.gelu(xn @ pd[p + "mlp.fc.w"] + pd[p + "mlp.fc.b"], approximate=True)
            x = x + (hdn @ pd[p + "mlp.proj.w"] + pd[p + "mlp.proj.b"])
        x = ln(x, pd["lnf.g"], pd["lnf.b"])
        return x @ pd["lm_head.w"]

    def f(fp):
        logits = plain_forward(fp)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        return -jnp.mean(ll)

    loss, grads = jax.value_and_grad(f)(flat_params)
    return loss, grads


def grad_sqnorms(cfg: GPTConfig, flat_grads):
    """Per-layer-type squared norms of an (accumulated) gradient.

    Applied by the coordinator to the big-batch gradient to obtain the
    ||G_Bbig||^2 component of Eqs. 4/5, per type, plus the total.
    """
    spec = param_spec(cfg)
    sums = {k: jnp.zeros(()) for k in layers.STATS_ORDER}
    for (name, _, ltype, _), g in zip(spec, flat_grads):
        sums[ltype] = sums[ltype] + jnp.sum(jnp.square(g))
    return jnp.stack([sums[k] for k in layers.STATS_ORDER])


def accumulate(flat_acc, flat_grads):
    return [a + g for a, g in zip(flat_acc, flat_grads)]


def adamw_update(cfg: GPTConfig, flat_params, flat_m, flat_v, flat_grads,
                 step, lr, grad_scale,
                 beta1=0.9, beta2=0.95, eps=1e-8, wd=0.1):
    """AdamW with decoupled weight decay on matrix params only (nanoGPT).

    ``grad_scale`` divides the accumulated gradient sum by the number of
    accumulation steps, folding the mean into the update (saves a pass).
    """
    spec = param_spec(cfg)
    new_p, new_m, new_v = [], [], []
    for (name, _, _, decay), p, m, v, g in zip(
        spec, flat_params, flat_m, flat_v, flat_grads
    ):
        g = g * grad_scale
        p2, m2, v2 = ref.adamw_step(
            p, m, v, g, step, lr, beta1, beta2, eps, wd if decay else 0.0
        )
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    return new_p, new_m, new_v


def eval_step(cfg: GPTConfig, flat_params, ids, targets):
    probes = layers.zero_probes()
    return loss_fn(cfg, flat_params, probes, ids, targets)
